"""Distributed realization of the Hybrid Coded MapReduce shuffle in JAX.

Two executable forms:

1. :func:`hybrid_shuffle_r2` — a shard_map program over a ('rack', 'server')
   mesh performing the paper's two-stage shuffle with `jax.lax.all_to_all`:
   a cross-rack stage over the 'rack' axis, then an intra-rack stage over the
   'server' axis.  Map replication r = 2 (the case the paper optimizes in
   Sec. IV).  Each of the r replicas sources 1/r of every needed block, which
   achieves the receive-side optimum  QN(1 - r/P)  per rack on point-to-point
   links.

   Fidelity note (see DESIGN.md): the paper counts a multicast packet ONCE at
   the root switch, giving the stronger (QN/r)(1 - r/P) *switch-traversal*
   cost.  TPU ICI/DCN expose no multicast primitive, so the executable path
   realizes the receive-side optimum while the switch-traversal metric is
   reproduced bit-exactly by the schedule simulator
   (:mod:`repro.core.shuffle_plan`).  For SUM-reducible shuffles (gradient
   aggregation) the linear-combining gain *is* natively realized on the wire
   by reduce-scatter — see :mod:`repro.core.gradient_sync`.

2. :func:`plan_shuffle_reference` — a dense single-device oracle for
   validating the distributed outputs bit-exactly.

Data model: intermediate values form V[N, Q, d] (subfile, key, payload);
reducer of key q needs q's value on ALL N subfiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .assignment import hybrid_assignment, rack_subsets
from .params import SchemeParams


# ---------------------------------------------------------------------------
# Plan compilation: static index tables for the r = 2 hybrid shuffle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HybridShufflePlanR2:
    params: SchemeParams
    # global subfile ids mapped at device (rack i, layer j): [P, Kr, n_loc]
    local_subfiles: np.ndarray
    # cross-stage: local subfile positions to send to rack z: [P, Kr, P, n_send]
    cross_send_pos: np.ndarray
    # canonical layer table (global subfile id per row): [P, Kr, n_layer]
    layer_subfiles: np.ndarray
    # positions in the layer table where rack a's block lands: [P, Kr, P, n_send]
    cross_recv_pos: np.ndarray
    # layer-table rows mapped locally: [P, Kr, n_layer] bool
    local_mask: np.ndarray
    n_send: int


def compile_hybrid_plan_r2(p: SchemeParams) -> HybridShufflePlanR2:
    p.validate_hybrid()
    if p.r != 2:
        raise ValueError("distributed executable path supports r = 2 "
                         "(the case the paper's Sec. IV optimizes)")
    a = hybrid_assignment(p)
    subsets = rack_subsets(p.P, p.r)
    slot_of = a.meta["slot_of_subfile"]

    n_loc = 2 * p.N // p.K
    n_layer = p.subfiles_per_layer
    M = p.M
    if M % 2 != 0:
        raise ValueError("executable r=2 plan needs 2 | M")
    half = M // 2
    n_send = (p.P - 2) * half if p.P >= 3 else 0

    files = {}
    for subfile, (layer, t_idx, w) in enumerate(slot_of):
        files.setdefault((layer, t_idx), [None] * M)[w] = subfile

    layer_table = np.zeros((p.P, p.Kr, n_layer), dtype=np.int64)
    local_subfiles = np.zeros((p.P, p.Kr, n_loc), dtype=np.int64)
    local_mask = np.zeros((p.P, p.Kr, n_layer), dtype=bool)
    cross_send_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)
    cross_recv_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)

    for j in range(p.Kr):
        flat = []
        for t_idx in range(len(subsets)):
            flat.extend(files[(j, t_idx)])
        for i in range(p.P):
            layer_table[i, j] = flat
            loc = [s for t_idx, T in enumerate(subsets) if i in T
                   for s in files[(j, t_idx)]]
            local_subfiles[i, j] = loc
            for t_idx, T in enumerate(subsets):
                if i in T:
                    local_mask[i, j, t_idx * M:(t_idx + 1) * M] = True

    for i in range(p.P):
        for j in range(p.Kr):
            loc_list = local_subfiles[i, j].tolist()
            table = layer_table[i, j].tolist()
            for z in range(p.P):
                if z == i or n_send == 0:
                    continue
                send, recv_from_z = [], []
                for t_idx, T in enumerate(subsets):
                    subs = files[(j, t_idx)]
                    if i in T and z not in T:
                        pos = T.index(i)
                        send.extend(loc_list.index(s)
                                    for s in subs[pos * half:(pos + 1) * half])
                    if z in T and i not in T:
                        pos = T.index(z)
                        recv_from_z.extend(
                            table.index(s)
                            for s in subs[pos * half:(pos + 1) * half])
                cross_send_pos[i, j, z, :] = send
                cross_recv_pos[i, j, z, :] = recv_from_z
    return HybridShufflePlanR2(p, local_subfiles, cross_send_pos, layer_table,
                               cross_recv_pos, local_mask, n_send)


# ---------------------------------------------------------------------------
# Distributed execution (shard_map over ('rack', 'server'))
# ---------------------------------------------------------------------------

def hybrid_shuffle_r2(values_local: jax.Array, plan: HybridShufflePlanR2,
                      mesh: Mesh) -> jax.Array:
    """Two-stage hybrid shuffle.

    values_local: [K, n_loc, Q, d], axis 0 sharded over ('rack','server');
      row (i*Kr + j) = device (i, j)'s mapped subfile values, ordered as
      ``plan.local_subfiles[i, j]``.
    Returns [K, N, q_srv, d]: per device, values of ALL N subfiles for its own
      q_srv reduce keys, rows ordered as :func:`reduce_ready_order`.
    """
    p = plan.params
    q_rack, q_srv = p.Q // p.P, p.Q // p.K
    n_layer = p.subfiles_per_layer
    d = values_local.shape[-1]
    n_send = plan.n_send

    send_pos = jnp.asarray(plan.cross_send_pos)      # [P, Kr, P, n_send]
    recv_pos = jnp.asarray(plan.cross_recv_pos)
    local_pos = jnp.asarray(
        np.array([[[plan.layer_subfiles[i, j].tolist().index(s)
                    for s in plan.local_subfiles[i, j]]
                   for j in range(p.Kr)] for i in range(p.P)]))  # [P,Kr,n_loc]

    def device_fn(vals):                             # [1, n_loc, Q, d]
        vals = vals[0]
        i = jax.lax.axis_index("rack")
        j = jax.lax.axis_index("server")
        my_send = send_pos[i, j]                     # [P, n_send]
        my_recv = recv_pos[i, j]
        my_local = local_pos[i, j]                   # [n_loc]
        key_starts = jnp.arange(p.P) * q_rack

        # ---- Stage 1: cross-rack all_to_all over 'rack' --------------------
        table = jnp.zeros((n_layer, q_rack, d), vals.dtype)
        my_keys = jax.lax.dynamic_slice_in_dim(vals, i * q_rack, q_rack, 1)
        table = table.at[my_local].set(my_keys)      # locally mapped rows
        if n_send > 0:
            def build_block(z):
                rows = jnp.take(vals, my_send[z], axis=0)   # [n_send, Q, d]
                return jax.lax.dynamic_slice_in_dim(
                    rows, key_starts[z], q_rack, 1)         # [n_send, qr, d]
            blocks = jax.vmap(build_block)(jnp.arange(p.P))  # [P,n_send,qr,d]
            recvd = jax.lax.all_to_all(blocks, "rack", split_axis=0,
                                       concat_axis=0, tiled=True)
            flat_dst = my_recv.reshape(-1)                   # [P*n_send]
            flat_src = recvd.reshape(p.P * n_send, q_rack, d)
            valid = (jnp.repeat(jnp.arange(p.P), n_send) != i)
            # target rows start at zero and are hit at most once => add==set
            table = table.at[flat_dst].add(
                jnp.where(valid[:, None, None], flat_src, 0))

        # ---- Stage 2: intra-rack all_to_all over 'server' ------------------
        per_srv = table.reshape(n_layer, p.Kr, q_srv, d).transpose(1, 0, 2, 3)
        gathered = jax.lax.all_to_all(per_srv, "server", split_axis=0,
                                      concat_axis=0, tiled=True)
        out = gathered.reshape(p.Kr * n_layer, q_srv, d)
        return out[None]

    fn = jax.shard_map(device_fn, mesh=mesh,
                       in_specs=(P(("rack", "server")),),
                       out_specs=P(("rack", "server")))
    return fn(values_local)


def reduce_ready_order(plan: HybridShufflePlanR2) -> np.ndarray:
    """Global subfile id of each output row of :func:`hybrid_shuffle_r2`,
    per device: [P, Kr, N] (layer-major, canonical layer-table order)."""
    p = plan.params
    out = np.zeros((p.P, p.Kr, p.N), dtype=np.int64)
    for i in range(p.P):
        for j in range(p.Kr):
            rows = []
            for jp in range(p.Kr):
                rows.extend(plan.layer_subfiles[i, jp].tolist())
            out[i, j] = rows
    return out


def pack_local_values(values: np.ndarray,
                      plan: HybridShufflePlanR2) -> np.ndarray:
    """Distribute dense V[N, Q, d] into the per-device layout expected by
    :func:`hybrid_shuffle_r2`: [K, n_loc, Q, d]."""
    p = plan.params
    out = np.stack([
        values[plan.local_subfiles[i, j]]
        for i in range(p.P) for j in range(p.Kr)
    ])
    return out


def plan_shuffle_reference(values: np.ndarray, p: SchemeParams) -> np.ndarray:
    """Oracle: [K, N, q_srv, d] that a correct shuffle must deliver, in the
    row order of :func:`reduce_ready_order`."""
    plan = compile_hybrid_plan_r2(p)
    order = reduce_ready_order(plan)
    q_srv = p.Q // p.K
    out = np.zeros((p.K, p.N, q_srv, values.shape[-1]), values.dtype)
    for i in range(p.P):
        for j in range(p.Kr):
            s = p.server_id(i, j)
            keys = list(p.keys_of_server(s))
            out[s] = values[order[i, j]][:, keys, :]
    return out
