"""Section IV — data-locality-aware Map-task assignment.

Valid Hybrid-Coded-MapReduce assignments are exactly the permutations of
subfiles over the structural slots (layer, rack-subset, w); Theorem IV.1's
four constraints characterize them.  Choosing the permutation that maximizes

    sum_i C(i, pair_i),   C(i,j,k) = lam*NodeLocality + (1-lam)*RackLocality

is a transportation problem: N subfiles -> (layer, rack-subset) groups of
capacity M, with a per-(subfile, group) score.  Flow integrality makes the
LP optimum integral, so min-cost max-flow solves the integer program of
Theorem IV.1 EXACTLY (the paper leaves the solver unspecified).

A greedy solver and the random baseline of Table II are also provided.
"""
from __future__ import annotations

import dataclasses
import heapq
from math import comb
from typing import List, Sequence, Tuple

import numpy as np

from .assignment import rack_subsets, slot_servers
from .params import SchemeParams


# ---------------------------------------------------------------------------
# Storage replica placement (HDFS-style)
# ---------------------------------------------------------------------------

def place_replicas(p: SchemeParams, rng: np.random.Generator,
                   policy: str = "uniform") -> np.ndarray:
    """Replica locations, shape [N, r_f]; no two replicas share a server.

    ``uniform``: r_f distinct servers uniformly at random (the paper's model).
    ``hdfs``: first replica uniform; second in a different rack; third in the
    second's rack on a different server (Hadoop default for r_f = 3).

    Both policies draw all N subfiles' placements in batched ``rng`` calls
    (the per-subfile Python loop was the Table II setup bottleneck).
    """
    if policy == "uniform":
        # row-wise uniform random permutation of the K servers, truncated to
        # r_f: identical in distribution to ordered sampling without
        # replacement (rng.choice(K, r_f, replace=False) per row).
        return np.argsort(rng.random((p.N, p.K)), axis=1)[:, :p.r_f] \
            .astype(np.int64)
    if policy != "hdfs":
        raise ValueError(policy)

    out = np.zeros((p.N, p.r_f), dtype=np.int64)
    first = rng.integers(p.K, size=p.N)
    out[:, 0] = first
    if p.r_f >= 2:
        # uniform over the K - Kr servers outside first's rack: draw a rack
        # offset in [1, P) and a slot in [0, Kr)
        rack2 = (first // p.Kr + rng.integers(1, p.P, size=p.N)) % p.P
        out[:, 1] = rack2 * p.Kr + rng.integers(p.Kr, size=p.N)
    if p.r_f >= 3:
        # same rack as the second replica, different slot
        slot3 = (out[:, 1] % p.Kr + rng.integers(1, p.Kr, size=p.N)) % p.Kr
        out[:, 2] = (out[:, 1] // p.Kr) * p.Kr + slot3
    for c in range(3, p.r_f):
        # replicas past the Hadoop triple: uniform over the unchosen servers
        taken = np.zeros((p.N, p.K), dtype=bool)
        np.put_along_axis(taken, out[:, :c], True, axis=1)
        scores = np.where(taken, np.inf, rng.random((p.N, p.K)))
        out[:, c] = scores.argmin(axis=1)
    return out


# ---------------------------------------------------------------------------
# Locality measure  C(i, j, k)
# ---------------------------------------------------------------------------

def group_servers(p: SchemeParams) -> List[Tuple[int, ...]]:
    """Server tuple of every (layer, rack-subset) group, group-major order
    matching :func:`repro.core.assignment.hybrid_slots`."""
    subsets = rack_subsets(p.P, p.r)
    out = []
    for layer in range(p.n_layers):
        for t_idx in range(len(subsets)):
            out.append(slot_servers(p, layer, t_idx))
    return out


def _locality_incidence(p: SchemeParams, replicas: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(node[i, g], rack[i, g]) integer hit counts of assigning subfile i to
    group g: how many of g's servers host a replica of i / sit in a rack that
    hosts one.  Built as one-hot replica/rack incidence matmuls — the
    O(N*G*r) Python triple loop collapsed to two [N, K] @ [K, G] products."""
    groups = np.asarray(group_servers(p), dtype=np.int64)     # [G, r]
    G = groups.shape[0]
    # replica one-hot incidences
    has_server = np.zeros((p.N, p.K), dtype=np.int64)         # [N, K]
    has_server[np.arange(p.N)[:, None], replicas.astype(np.int64)] = 1
    has_rack = np.zeros((p.N, p.P), dtype=np.int64)           # [N, P] 0/1
    has_rack[np.arange(p.N)[:, None], replicas.astype(np.int64) // p.Kr] = 1
    # group-side incidences: server membership / per-rack server counts
    g_server = np.zeros((G, p.K), dtype=np.int64)
    g_server[np.arange(G)[:, None], groups] = 1               # distinct srvs
    g_rack = np.zeros((G, p.P), dtype=np.int64)
    np.add.at(g_rack, (np.repeat(np.arange(G), groups.shape[1]),
                       (groups // p.Kr).ravel()), 1)
    return has_server @ g_server.T, has_rack @ g_rack.T


def locality_matrix(p: SchemeParams, replicas: np.ndarray,
                    lam: float = 0.8) -> np.ndarray:
    """C[i, g] = lam*NodeLocality + (1-lam)*RackLocality of assigning subfile
    i to group g's server set (Section V's measure, generalized to r >= 2)."""
    if not (0.5 < lam <= 1.0):
        raise ValueError("paper requires lam in (0.5, 1]")
    node, rack = _locality_incidence(p, replicas)
    return lam * node + (1.0 - lam) * rack


def locality_of_perm(p: SchemeParams, replicas: np.ndarray,
                     perm: Sequence[int]) -> Tuple[float, float]:
    """(node_locality, rack_locality) in [0, 1] — Table II's percentages:
    fraction of (map-replica, server) placements that are local."""
    node, rack = _locality_incidence(p, replicas)
    # slot s belongs to group s // M (hybrid_slots is group-major, M per group)
    group_of_slot = np.arange(p.N) // p.M
    perm = np.asarray(perm, dtype=np.int64)
    denom = p.N * p.r
    return (int(node[perm, group_of_slot].sum()) / denom,
            int(rack[perm, group_of_slot].sum()) / denom)


# ---------------------------------------------------------------------------
# Solvers
# ---------------------------------------------------------------------------

def random_perm(p: SchemeParams, rng: np.random.Generator) -> np.ndarray:
    """Table II's 'Ran' baseline: an arbitrary valid hybrid assignment."""
    return rng.permutation(p.N)


def greedy_perm(p: SchemeParams, C: np.ndarray) -> np.ndarray:
    """Greedy: repeatedly place the highest-scoring (subfile, group) pair
    into a free slot.  Fast, near-optimal; used as a scalable fallback."""
    n_groups = C.shape[1]
    cap = np.full(n_groups, p.M, dtype=np.int64)
    order = np.argsort(-C, axis=None)
    assigned = np.full(p.N, -1, dtype=np.int64)
    placed = 0
    for flat in order:
        i, g = divmod(int(flat), n_groups)
        if assigned[i] >= 0 or cap[g] == 0:
            continue
        assigned[i] = g
        cap[g] -= 1
        placed += 1
        if placed == p.N:
            break
    return _groups_to_perm(p, assigned)


def optimal_perm(p: SchemeParams, C: np.ndarray) -> np.ndarray:
    """Exact solution of Theorem IV.1 via min-cost max-flow (SSP + Dijkstra
    with Johnson potentials).  Integral by flow integrality."""
    n, n_groups = C.shape
    # node ids: 0 = source, 1..n subfiles, n+1..n+n_groups groups, last = sink
    S, T = 0, n + n_groups + 1
    n_nodes = T + 1
    graph: List[List[int]] = [[] for _ in range(n_nodes)]
    # edge arrays
    to: List[int] = []
    cap: List[int] = []
    cost: List[float] = []

    def add_edge(u: int, v: int, c: int, w: float) -> None:
        graph[u].append(len(to)); to.append(v); cap.append(c); cost.append(w)
        graph[v].append(len(to)); to.append(u); cap.append(0); cost.append(-w)

    cmax = float(C.max()) if C.size else 0.0
    for i in range(n):
        add_edge(S, 1 + i, 1, 0.0)
        for g in range(n_groups):
            # shift costs so all are >= 0 for Dijkstra (maximize C == minimize
            # cmax - C); the shift is constant per unit flow, so argmin is
            # unchanged.
            add_edge(1 + i, 1 + n + g, 1, cmax - float(C[i, g]))
    for g in range(n_groups):
        add_edge(1 + n + g, T, p.M, 0.0)

    potential = np.zeros(n_nodes)
    flow_assigned = np.full(n, -1, dtype=np.int64)
    INF = float("inf")
    for _ in range(n):  # one augmentation per subfile (unit flows)
        dist = np.full(n_nodes, INF)
        dist[S] = 0.0
        prev_edge = np.full(n_nodes, -1, dtype=np.int64)
        pq = [(0.0, S)]
        while pq:
            d, u = heapq.heappop(pq)
            if d > dist[u] + 1e-12:
                continue
            for eid in graph[u]:
                if cap[eid] <= 0:
                    continue
                v = to[eid]
                nd = d + cost[eid] + potential[u] - potential[v]
                if nd < dist[v] - 1e-12:
                    dist[v] = nd
                    prev_edge[v] = eid
                    heapq.heappush(pq, (nd, v))
        assert dist[T] < INF, "flow infeasible: check divisibility of N"
        finite = dist < INF
        potential[finite] += dist[finite]
        # augment one unit along S->T
        v = T
        while v != S:
            eid = int(prev_edge[v])
            cap[eid] -= 1
            cap[eid ^ 1] += 1
            v = to[eid ^ 1]
    # read off subfile -> group assignment
    for i in range(n):
        for eid in graph[1 + i]:
            if to[eid] != S and cap[eid ^ 1] > 0 and eid % 2 == 0:
                flow_assigned[i] = to[eid] - 1 - n
                break
    assert (flow_assigned >= 0).all()
    return _groups_to_perm(p, flow_assigned)


def _groups_to_perm(p: SchemeParams, group_of_subfile: np.ndarray) -> np.ndarray:
    """Convert a subfile->group map into a slot permutation (slot_index ->
    subfile), filling each group's M slots in subfile order."""
    n_groups = int(group_of_subfile.max()) + 1 if len(group_of_subfile) else 0
    subsets = rack_subsets(p.P, p.r)
    n_groups = max(n_groups, p.n_layers * len(subsets))
    perm = np.full(p.N, -1, dtype=np.int64)
    next_w = np.zeros(n_groups, dtype=np.int64)
    for i in range(p.N):
        g = int(group_of_subfile[i])
        w = int(next_w[g]); next_w[g] += 1
        assert w < p.M, "group over capacity"
        slot_index = g * p.M + w
        perm[slot_index] = i
    assert (perm >= 0).all()
    return perm


# ---------------------------------------------------------------------------
# Table II driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LocalityResult:
    node_random: float
    rack_random: float
    node_opt: float
    rack_opt: float
    node_greedy: float
    rack_greedy: float


def table2_experiment(p: SchemeParams, lam: float = 0.8, seed: int = 0,
                      trials: int = 5, policy: str = "uniform",
                      solver: str = "optimal") -> LocalityResult:
    """Run Table II's comparison for one row, averaged over ``trials``
    random replica placements."""
    rng = np.random.default_rng(seed)
    acc = np.zeros(6)
    for _ in range(trials):
        replicas = place_replicas(p, rng, policy)
        C = locality_matrix(p, replicas, lam)
        rp = random_perm(p, rng)
        op = optimal_perm(p, C) if solver == "optimal" else greedy_perm(p, C)
        gp = greedy_perm(p, C)
        nr, rr = locality_of_perm(p, replicas, rp)
        no, ro = locality_of_perm(p, replicas, op)
        ng, rg = locality_of_perm(p, replicas, gp)
        acc += np.array([nr, rr, no, ro, ng, rg])
    acc /= trials
    return LocalityResult(*acc.tolist())
