"""Section IV — data-locality-aware Map-task assignment (compat facade).

The locality layer grew into the :mod:`repro.placement` subsystem —
general-r objectives, a solver registry (random / greedy / flow /
local_search / anneal_jax), structured replica placements, joint
replica+assignment optimization and a simulator bridge.  This module keeps
the original Section-IV API importable from ``repro.core.locality``:
every name below is a re-export, and ``optimal_perm`` is the registry's
``flow`` solver (min-cost max-flow, exact for Theorem IV.1).
"""
from __future__ import annotations

from ..placement.experiments import (LocalityResult, table2_experiment,
                                     table2_trials)
from ..placement.objectives import (group_servers, locality_incidence,
                                    locality_matrix, locality_of_perm,
                                    place_replicas)
from ..placement.solvers import (flow_perm, greedy_perm, groups_to_perm,
                                 random_perm)

# historical names
optimal_perm = flow_perm
_groups_to_perm = groups_to_perm
_locality_incidence = locality_incidence

__all__ = [
    "LocalityResult", "table2_experiment", "table2_trials", "group_servers",
    "locality_incidence", "locality_matrix", "locality_of_perm",
    "place_replicas", "flow_perm", "greedy_perm", "groups_to_perm",
    "random_perm", "optimal_perm",
]
