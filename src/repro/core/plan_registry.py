"""Plan-compiler registry: scheme families as first-class backends.

A *plan compiler* turns :class:`~repro.core.params.SchemeParams` (plus an
optional Section-IV slot permutation) into a :class:`HybridShufflePlan` —
the static index tables that drive the executable two-stage shuffle of
:mod:`repro.core.coded_collectives`.  Two families are registered:

  * ``binomial``   — the paper's Sec. III construction: per layer, the
    C(P, r) rack r-subsets each map M = (NP/K)/C(P, r) subfiles.  Multicast
    gain r, but the subfile count must satisfy C(P, r) | NP/K, which
    explodes combinatorially with P (the known Achilles' heel of CDC-style
    designs).
  * ``resolvable`` — a resolvable-design construction (Konstantinidis &
    Ramamoorthy, arXiv:1908.05666) from a single-parity-check code: the P
    racks split into r parallel classes of q = P/r, and the q^{r-1} SPC
    codewords index the subfile batches.  Multicast gain r - 1 with
    subpacketization q^{r-1} — the divisor demanded of NP/K is a plain
    prime power instead of a binomial, which is what lets K scale into the
    hundreds at practical (power-of-two) subfile counts.  See
    :mod:`repro.core.resolvable` and docs/scaling.md.

All compilers emit the SAME plan schema, so every consumer (the shard_map
device body, the fused engine, the Pallas coded-combine path, the sim's
traffic derivation) is family-agnostic.  Two schema extensions carry the
family-specific structure:

  * ``mcast_arity`` (the trailing dim of the ``mcast_comp_*`` tables) is
    the number of components per coded packet — r for binomial, r - 1 for
    resolvable — and replaces every hard-coded use of ``params.r`` in the
    encode/decode paths.
  * ``cross_valid`` marks which stage-1 slots of each (receiver, source)
    stream carry real data.  ``None`` (binomial) means every slot from a
    distinct rack is valid; the resolvable family pads its all_to_all
    blocks to a uniform n_send (same-class rack pairs exchange nothing),
    and the mask keeps the padding out of the layer table.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .params import SchemeParams

# Registered family names, in registration order (binomial first).
SCHEME_FAMILIES: Tuple[str, ...] = ("binomial", "resolvable")


@dataclasses.dataclass(frozen=True, eq=False)
class HybridShufflePlan:
    """Static index tables driving the executable hybrid shuffle, any r.

    Table layout is documented in :mod:`repro.core.coded_collectives`
    (binomial) and :mod:`repro.core.resolvable` (resolvable); the schema is
    shared — consumers dispatch on nothing but the tables themselves.
    """
    params: SchemeParams
    # global subfile ids mapped at device (rack i, layer j): [P, Kr, n_loc]
    local_subfiles: np.ndarray
    # cross-stage: local subfile positions to send to rack z: [P, Kr, P, n_send]
    cross_send_pos: np.ndarray
    # canonical layer table (global subfile id per row): [P, Kr, n_layer]
    layer_subfiles: np.ndarray
    # positions in the layer table where rack z's block lands: [P, Kr, P, n_send]
    cross_recv_pos: np.ndarray
    # layer-table rows mapped locally: [P, Kr, n_layer] bool
    local_mask: np.ndarray
    n_send: int
    # layer-table position of each locally mapped subfile: [P, Kr, n_loc]
    local_pos: np.ndarray
    # --- coded-multicast tables (the paper's f(.) on the wire) -------------
    # Packet m of sender rack i's stream to rack z combines `mcast_arity`
    # components, one per receiver rack in the multicast group; these are
    # all layer-independent (no Kr axis).  Empty ([P, P, 0, arity]) when
    # n_send = 0.
    # local position (in the sender's vals) of component c: [P,P,n_send,arity]
    mcast_comp_pos: np.ndarray
    # rack whose reduce-key block component c is destined to: [P,P,n_send,arity]
    mcast_comp_rack: np.ndarray
    # receiver side-information, receiver i <- source s: local position / key
    # rack of the arity-1 KNOWN components of each packet: [P,P,n_send,arity-1]
    mcast_known_pos: np.ndarray
    mcast_known_rack: np.ndarray
    # --- family extensions (defaults reproduce the binomial schema) --------
    family: str = "binomial"
    # stage-1 slot validity, receiver i <- source s: [P, P, n_send] bool.
    # None: every slot from s != i is valid (binomial's uniform streams).
    cross_valid: Optional[np.ndarray] = None

    @property
    def mcast_arity(self) -> int:
        """Components per coded stage-1 packet (r binomial, r-1 resolvable);
        coding degenerates to unicast when this is < 2."""
        return int(self.mcast_comp_pos.shape[-1])


# A plan compiler: (params, optional slot permutation) -> plan.  ``perm``
# places subfile perm[slot] into structural slot ``slot`` — the Section-IV
# locality degree of freedom, shared by every family.
PlanCompiler = Callable[
    [SchemeParams, Optional[Tuple[int, ...]]], HybridShufflePlan]

_PLAN_COMPILERS: Dict[str, PlanCompiler] = {}


def register_plan_compiler(family: str) -> Callable[[PlanCompiler],
                                                    PlanCompiler]:
    """Decorator registering ``fn`` as the compiler of ``family``.

    Compilers must be pure (same inputs -> bit-identical tables): the LRU
    plan cache of :mod:`repro.core.coded_collectives` memoizes on
    (params, perm, family) and shares the resulting plan object.
    """
    def deco(fn: PlanCompiler) -> PlanCompiler:
        if family in _PLAN_COMPILERS:
            raise ValueError(f"plan compiler {family!r} already registered")
        _PLAN_COMPILERS[family] = fn
        return fn
    return deco


def get_plan_compiler(family: str) -> PlanCompiler:
    if family not in _PLAN_COMPILERS:
        # built-in families register on import of their host modules; pull
        # them in so a bare `import repro.core.plan_registry` still resolves
        from . import coded_collectives  # noqa: F401
    try:
        return _PLAN_COMPILERS[family]
    except KeyError:
        raise ValueError(
            f"unknown scheme family {family!r}; registered: "
            f"{tuple(sorted(_PLAN_COMPILERS))}") from None


def plan_families() -> Tuple[str, ...]:
    """Registered family names, sorted."""
    return tuple(sorted(_PLAN_COMPILERS))


def scheme_of_family(family: str) -> str:
    """Sim/scheduler scheme string of a plan family ('hybrid' stays the
    binomial construction's name for back-compat)."""
    return "hybrid" if family == "binomial" else f"hybrid_{family}"


def family_of_scheme(scheme: str) -> Optional[str]:
    """Inverse of :func:`scheme_of_family`; None for non-hybrid schemes."""
    if scheme == "hybrid":
        return "binomial"
    if scheme.startswith("hybrid_"):
        return scheme[len("hybrid_"):]
    return None


def compile_degraded_plan(*args, **kwargs):
    """Registry-level entry point for degraded-mode plan recompilation —
    re-routes any registered family's plan around crashed servers.  Lazy
    re-export of :func:`repro.core.degraded.compile_degraded_plan` (that
    module imports the plan compilers, which import this one)."""
    from .degraded import compile_degraded_plan as impl
    return impl(*args, **kwargs)
