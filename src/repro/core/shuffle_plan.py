"""Explicit data-shuffling schedules for Uncoded / Coded / Hybrid MapReduce.

A *plan* is a deterministic sequence of :class:`Message`.  Counting the
messages of a plan must reproduce the closed forms in :mod:`repro.core.costs`
(that equality is asserted in tests — the schedules are the proof that the
formulas describe a realizable shuffle).

A coded message multicasts ONE linear combination of ``r`` intermediate
values; every intended receiver already knows all components except its own
(side information from replicated map tasks) and recovers its missing value
by subtraction.  :func:`execute_plan` simulates exactly that on integer
payloads and asserts information-completeness at every step, which validates
decodability of the whole schedule — the paper's central claim.
"""
from __future__ import annotations

import dataclasses
import itertools
from math import comb
from typing import Dict, Iterable, Iterator, List, Tuple

import numpy as np

from .assignment import Assignment, rack_subsets
from .params import SchemeParams

# One component of a (possibly coded) message: this message lets `receiver`
# recover the value of `key` computed on `subfile`.
Component = Tuple[int, int, int]            # (receiver, key, subfile)


@dataclasses.dataclass(frozen=True)
class Message:
    sender: int
    components: Tuple[Component, ...]       # r components for a coded msg
    stage: str                              # 'shuffle' | 'cross' | 'intra'

    @property
    def receivers(self) -> Tuple[int, ...]:
        return tuple(sorted({c[0] for c in self.components}))

    def is_cross(self, p: SchemeParams) -> bool:
        """A message uses the root switch iff any receiver is outside the
        sender's rack (the paper attributes the whole multicast to the root
        switch in that case)."""
        my_rack = p.rack_of(self.sender)
        return any(p.rack_of(rcv) != my_rack for rcv in self.receivers)


@dataclasses.dataclass
class PlanCounts:
    intra: int = 0
    cross: int = 0

    @property
    def total(self) -> int:
        return self.intra + self.cross


def count_plan(plan: Iterable[Message], p: SchemeParams) -> PlanCounts:
    counts = PlanCounts()
    for m in plan:
        if m.is_cross(p):
            counts.cross += 1
        else:
            counts.intra += 1
    return counts


# ---------------------------------------------------------------------------
# Plan generators
# ---------------------------------------------------------------------------

def uncoded_plan(assignment: Assignment) -> Iterator[Message]:
    """Every mapper unicasts each (key, subfile) value to the key's reducer."""
    p = assignment.params
    for subfile, servers in enumerate(assignment.servers_of_subfile):
        (mapper,) = servers
        for key in range(p.Q):
            reducer = p.server_of_key(key)
            if reducer != mapper:
                yield Message(mapper, ((reducer, key, subfile),), "shuffle")


def _chunk(subfiles: List[int], sender_pos: int, n_senders: int) -> List[int]:
    """The sender's share of a receiver's needed subfiles (paper splits the
    M (resp. J) subfiles evenly among the r senders)."""
    per = len(subfiles) // n_senders
    return subfiles[sender_pos * per:(sender_pos + 1) * per]


def coded_plan(assignment: Assignment) -> Iterator[Message]:
    """Coded MapReduce shuffle (Prop. 2 schedule).

    For every (r+1)-subset S of servers, every member `a` multicasts
    (Q/K) * (J/r) coded messages; the message for (u, w) combines, for each
    receiver z in S \\ {a}, the value of z's u-th reduce key on the w-th
    subfile of a's share of the subfiles mapped at T_z = S \\ {z}.
    """
    p = assignment.params
    r = p.r
    if p.J % max(r, 1) != 0:
        raise ValueError(f"executable coded plan needs r|J; J={p.J} r={r}")
    q_per = p.Q // p.K

    # subfiles per server-subset, in deterministic order
    by_subset: Dict[Tuple[int, ...], List[int]] = {}
    for i, servers in enumerate(assignment.servers_of_subfile):
        by_subset.setdefault(tuple(servers), []).append(i)

    for S in itertools.combinations(range(p.K), r + 1):
        for a in S:
            others = [z for z in S if z != a]
            for w in range(p.J // r):
                for u in range(q_per):
                    comps = []
                    for z in others:
                        T_z = tuple(s for s in S if s != z)
                        pos = T_z.index(a)
                        sub = _chunk(by_subset[T_z], pos, r)[w]
                        key = list(p.keys_of_server(z))[u]
                        comps.append((z, key, sub))
                    yield Message(a, tuple(comps), "shuffle")


def hybrid_plan(assignment: Assignment) -> Iterator[Message]:
    """Hybrid Coded MapReduce shuffle (Sec. III schedule): a cross-rack coded
    stage per layer followed by an uncoded intra-rack stage."""
    p = assignment.params
    r = p.r
    if r >= 1 and p.M % max(r, 1) != 0:
        raise ValueError(f"executable hybrid plan needs r|M; M={p.M} r={r}")
    subsets = rack_subsets(p.P, r)
    q_per_rack = p.Q // p.P

    # layer -> rack-subset -> subfiles (deterministic order)
    slot_of = assignment.meta["slot_of_subfile"]
    by_layer_subset: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for subfile, (layer, t_idx, w) in enumerate(slot_of):  # type: ignore[arg-type]
        by_layer_subset.setdefault((layer, t_idx), []).append((w, subfile))
    layer_subset_files = {
        k: [sub for _, sub in sorted(v)] for k, v in by_layer_subset.items()
    }

    # ---- Stage 1: cross-rack coded multicasts, independently per layer ------
    for layer in range(p.n_layers):
        for S in itertools.combinations(range(p.P), r + 1):  # racks
            for a_rack in S:
                sender = p.server_id(a_rack, layer)
                others = [z for z in S if z != a_rack]
                for w in range(p.M // r):
                    for u in range(q_per_rack):
                        comps = []
                        for z_rack in others:
                            T_z = tuple(x for x in S if x != z_rack)
                            t_idx = subsets.index(T_z)
                            pos = T_z.index(a_rack)
                            files = layer_subset_files[(layer, t_idx)]
                            sub = _chunk(files, pos, r)[w]
                            key = list(p.keys_of_rack(z_rack))[u]
                            comps.append((p.server_id(z_rack, layer), key, sub))
                        yield Message(sender, tuple(comps), "cross")

    # ---- Stage 2: intra-rack unicast ----------------------------------------
    # After stage 1, server (rack, layer) holds the values of ALL subfiles of
    # its layer for ALL of its rack's keys; it forwards each in-rack peer's
    # reduce keys for every layer subfile.
    per_layer = p.subfiles_per_layer
    layer_files: Dict[int, List[int]] = {la: [] for la in range(p.n_layers)}
    for subfile, (layer, t_idx, w) in enumerate(slot_of):  # type: ignore[arg-type]
        layer_files[layer].append(subfile)
    for layer in range(p.n_layers):
        assert len(layer_files[layer]) == per_layer
        for rack in range(p.P):
            sender = p.server_id(rack, layer)
            for subfile in layer_files[layer]:
                for key in p.keys_of_rack(rack):
                    reducer = p.server_of_key(key)
                    if reducer != sender:
                        yield Message(sender, ((reducer, key, subfile),),
                                      "intra")


def resolvable_hybrid_plan(assignment: Assignment) -> Iterator[Message]:
    """Resolvable-design hybrid shuffle (see :mod:`repro.core.resolvable`):
    per layer, one coded multicast stream per (non-codeword group, sender
    class); stage 2 is the hybrid scheme's intra-rack unicast verbatim.

    Each message combines r-1 components — one per fellow group member —
    and every receiver maps all other members' missing batches (side
    information), so :func:`execute_plan`'s strict decodability assertions
    prove the schedule, and its counts reproduce
    :func:`repro.core.costs.hybrid_resolvable_cost` (asserted in tests).
    """
    from .resolvable import needed_batch, spc_codewords

    p = assignment.params
    p.validate_hybrid_resolvable()
    q, r = p.spc_q, p.r
    q_per_rack = p.Q // p.P
    cw = spc_codewords(q, r)
    codeword_set = {tuple(c) for c in cw.tolist()}

    slot_of = assignment.meta["slot_of_subfile"]
    # (layer, batch) -> subfiles in w order
    by_layer_batch: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for subfile, (layer, t_idx, w) in enumerate(slot_of):  # type: ignore[arg-type]
        by_layer_batch.setdefault((layer, t_idx), []).append((w, subfile))
    batch_files = {k: [sub for _, sub in sorted(v)]
                   for k, v in by_layer_batch.items()}

    # ---- Stage 1: cross-rack coded multicasts, independently per layer ----
    from .resolvable import batch_index
    for layer in range(p.n_layers):
        for g in itertools.product(range(q), repeat=r):
            if g in codeword_set:
                continue
            for a_cls in range(r):
                a_rack = a_cls * q + g[a_cls]
                sender = p.server_id(a_rack, layer)
                others = [t for t in range(r) if t != a_cls]
                for w in range(p.M_res // (r - 1)):
                    for u in range(q_per_rack):
                        comps = []
                        for t_cls in others:
                            b_t = needed_batch(g, t_cls, q)
                            t_idx = int(batch_index(b_t, q))
                            z_rack = t_cls * q + g[t_cls]
                            pos = a_cls if a_cls < t_cls else a_cls - 1
                            files = batch_files[(layer, t_idx)]
                            sub = _chunk(files, pos, r - 1)[w]
                            key = list(p.keys_of_rack(z_rack))[u]
                            comps.append((p.server_id(z_rack, layer), key,
                                          sub))
                        yield Message(sender, tuple(comps), "cross")

    # ---- Stage 2: intra-rack unicast (identical to the binomial family) ---
    per_layer = p.subfiles_per_layer
    layer_files: Dict[int, List[int]] = {la: [] for la in range(p.n_layers)}
    for subfile, (layer, t_idx, w) in enumerate(slot_of):  # type: ignore[arg-type]
        layer_files[layer].append(subfile)
    for layer in range(p.n_layers):
        assert len(layer_files[layer]) == per_layer
        for rack in range(p.P):
            sender = p.server_id(rack, layer)
            for subfile in layer_files[layer]:
                for key in p.keys_of_rack(rack):
                    reducer = p.server_of_key(key)
                    if reducer != sender:
                        yield Message(sender, ((reducer, key, subfile),),
                                      "intra")


def make_plan(assignment: Assignment) -> Iterator[Message]:
    return {"uncoded": uncoded_plan,
            "coded": coded_plan,
            "hybrid": hybrid_plan,
            "hybrid_resolvable": resolvable_hybrid_plan}[
        assignment.scheme](assignment)


# ---------------------------------------------------------------------------
# Bit-exact execution: proves every schedule is decodable & complete
# ---------------------------------------------------------------------------

def execute_plan(assignment: Assignment,
                 values: np.ndarray,
                 plan: Iterable[Message] | None = None,
                 strict: bool = True) -> List[Dict[Tuple[int, int], int]]:
    """Simulate the shuffle on integer map outputs ``values[subfile, key]``.

    Each server starts knowing values for the subfiles it mapped (all Q keys).
    Coded messages carry the SUM of their component values; a receiver must
    already know every component except its own (asserted when ``strict``)
    and decodes by subtraction.  Returns per-server knowledge dicts; callers
    assert reduce-readiness via :func:`check_reduce_ready`.
    """
    p = assignment.params
    know: List[Dict[Tuple[int, int], int]] = [dict() for _ in range(p.K)]
    for subfile, servers in enumerate(assignment.servers_of_subfile):
        for s in servers:
            for key in range(p.Q):
                know[s][(key, subfile)] = int(values[subfile, key])

    if plan is None:
        plan = make_plan(assignment)
    for m in plan:
        payload = sum(int(values[sub, key]) for (_, key, sub) in m.components)
        if strict:
            for (_, key, sub) in m.components:
                assert (key, sub) in know[m.sender], (
                    f"sender {m.sender} does not know {(key, sub)}")
        for (rcv, key, sub) in m.components:
            side = 0
            for (rcv2, key2, sub2) in m.components:
                if (rcv2, key2, sub2) != (rcv, key, sub):
                    if strict:
                        assert (key2, sub2) in know[rcv], (
                            f"receiver {rcv} lacks side info {(key2, sub2)}")
                    side += know[rcv].get((key2, sub2), int(values[sub2, key2]))
            know[rcv][(key, sub)] = payload - side
    return know


# ---------------------------------------------------------------------------
# Stage traffic export (consumed by the repro.sim network model)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageTraffic:
    """Aggregate link loads of one sequential shuffle stage, in pairs.

    ``cross_pairs`` counts root-switch traversals (a multicast counted ONCE,
    the paper metric); ``intra_pairs_per_rack[q]`` counts pairs through rack
    q's ToR switch.  A scheme's shuffle is a SEQUENCE of stages: the hybrid
    scheme is literally sequential (cross coded stage, then intra unicast);
    for uncoded/coded the single mixed stage is split into its cross and
    intra components, matching the serialization assumed by
    :meth:`repro.core.costs.CommCost.weighted_time`.
    """
    stage: str                              # 'cross' | 'intra'
    cross_pairs: float
    intra_pairs_per_rack: Tuple[float, ...]

    @property
    def intra_pairs(self) -> float:
        return float(sum(self.intra_pairs_per_rack))


def _as_stages(cross: float, intra_per_rack: np.ndarray) -> List[StageTraffic]:
    stages = []
    if cross > 0:
        stages.append(StageTraffic("cross", float(cross),
                                   tuple(0.0 for _ in intra_per_rack)))
    if intra_per_rack.sum() > 0:
        stages.append(StageTraffic("intra", 0.0,
                                   tuple(float(x) for x in intra_per_rack)))
    return stages


def plan_stage_traffic(assignment: Assignment) -> List[StageTraffic]:
    """Enumerate the scheme's explicit schedule into per-stage link loads.

    Exact per-rack attribution: an intra message loads its sender's ToR;
    a cross message loads the root once (multicast counted once).  Totals
    are proven equal to the closed forms in tests.
    """
    p = assignment.params
    cross = 0.0
    intra = np.zeros(p.P)
    for m in make_plan(assignment):
        if m.is_cross(p):
            cross += 1.0
        else:
            intra[p.rack_of(m.sender)] += 1.0
    return _as_stages(cross, intra)


def scheme_stage_traffic(p: SchemeParams, scheme: str,
                         check: bool = True) -> List[StageTraffic]:
    """Closed-form stage traffic (Props 1-2 / Thm III.1, balanced per-rack
    split — all three designs are rack-symmetric).  O(1); use this for large
    N where enumerating the schedule is too slow."""
    from .costs import (coded_cost, hybrid_cost, hybrid_resolvable_cost,
                        uncoded_cost)
    cost_fn = {"uncoded": uncoded_cost, "coded": coded_cost,
               "hybrid": hybrid_cost,
               "hybrid_resolvable": hybrid_resolvable_cost}[scheme]
    c = cost_fn(p, check=check)
    return _as_stages(c.cross, np.full(p.P, c.intra / p.P))


def check_reduce_ready(assignment: Assignment,
                       know: List[Dict[Tuple[int, int], int]],
                       values: np.ndarray) -> None:
    """Every server must hold the correct value of each of its reduce keys on
    every subfile."""
    p = assignment.params
    for server in range(p.K):
        for key in p.keys_of_server(server):
            for subfile in range(p.N):
                got = know[server].get((key, subfile))
                assert got is not None, (server, key, subfile)
                assert got == int(values[subfile, key]), (server, key, subfile)
