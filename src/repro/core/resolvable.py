"""Resolvable-design shuffle construction (the low-subpacketization family).

Adapts the single-parity-check (SPC) resolvable designs of Konstantinidis &
Ramamoorthy (arXiv:1908.05666) to the paper's server-rack hybrid scheme:
coding runs ACROSS RACKS within each server layer, exactly like the
binomial Sec. III construction, but the rack r-subsets are replaced by the
parallel classes of an SPC code, collapsing the subpacketization from
C(P, r) to q^{r-1} with q = P / r.

Construction (per layer, P = r * q racks, q >= 2):

  * Rack i belongs to *class* i // q with *value* i % q — the r parallel
    classes of the design.
  * The layer's NP/K subfiles split into B = q^{r-1} *batches* indexed by
    the codewords of the (r, r-1) SPC code over Z_q (last symbol = sum of
    the first r-1, mod q), M = (NP/K)/B subfiles per batch.  Batch b is
    mapped at the r racks {(class t, value b_t)} — one per class, so every
    subfile is mapped r times and every rack maps B/q = q^{r-2} batches:
    the same computation load r N/K as the binomial family.
  * Stage-1 multicast groups are the NON-codewords g in Z_q^r: the r racks
    {(t, g_t)} miss exactly one batch each — member (t, g_t) needs the
    unique codeword b(g, t) agreeing with g off coordinate t, which every
    OTHER member maps (side information).  Each member's missing M-subfile
    block splits into r-1 shares; each of its r-1 peers multicasts one
    coded packet stream combining its shares for all r-1 fellow members,
    so every packet serves r-1 receivers and traverses the root once:
    multicast gain r - 1.
  * Stage 2 (intra-rack) is identical to the binomial family.

Costs (Theorem III.1 analogue, proven against the enumerated schedule in
tests):  cross = QN/(r-1) * (1 - r/P),  intra = QN * (1 - P/K).

The win is the divisibility demand: NP/K must be a multiple of q^{r-1}
(a plain prime power when q is one) instead of C(P, r) — at power-of-two
subfile counts the binomial family is infeasible beyond P = 2 while this
family scales P (hence K) by orders of magnitude.  See docs/scaling.md and
``benchmarks/scale_bench.py``.

The compiled plan shares :class:`~repro.core.plan_registry.HybridShufflePlan`
with the binomial family: packets have ``mcast_arity`` = r - 1 components,
and because same-class rack pairs exchange nothing, the all_to_all streams
are padded to a uniform ``n_send`` with ``cross_valid`` masking the padding.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .assignment import Assignment
from .params import SchemeParams
from .plan_registry import HybridShufflePlan, register_plan_compiler


# ---------------------------------------------------------------------------
# SPC-code machinery (shared with repro.placement.structured)
# ---------------------------------------------------------------------------

def spc_codewords(q: int, r: int) -> np.ndarray:
    """All q^{r-1} codewords of the (r, r-1) SPC code over Z_q, as an
    [B, r] int64 array in lexicographic order of the first r-1 symbols
    (the batch enumeration order of the resolvable design)."""
    if q < 2 or r < 2:
        raise ValueError(f"SPC code needs q >= 2 and r >= 2; q={q} r={r}")
    B = q ** (r - 1)
    grids = np.meshgrid(*[np.arange(q)] * (r - 1), indexing="ij")
    info = np.stack([g.reshape(-1) for g in grids], axis=1) if r > 1 \
        else np.zeros((B, 0), np.int64)
    parity = info.sum(axis=1) % q
    return np.concatenate([info, parity[:, None]], axis=1).astype(np.int64)


def batch_index(coords: np.ndarray, q: int) -> np.ndarray:
    """Lexicographic batch index of codeword(s) from their first r-1
    symbols (base-q digits, most-significant first)."""
    coords = np.asarray(coords, dtype=np.int64)
    info = coords[..., :-1]
    weights = q ** np.arange(info.shape[-1] - 1, -1, -1, dtype=np.int64)
    return (info * weights).sum(axis=-1)


def needed_batch(g: Sequence[int], t: int, q: int) -> np.ndarray:
    """The unique codeword agreeing with group vector ``g`` on every
    coordinate except ``t`` (the batch that group member (t, g_t) is
    missing).  For a non-codeword g its t-th symbol differs from g_t."""
    b = np.asarray(g, dtype=np.int64).copy()
    r = len(b)
    if t == r - 1:
        b[t] = b[:-1].sum() % q
    else:
        b[t] = (b[-1] - (b[:-1].sum() - b[t])) % q
    return b


def cyclic_replica_server(p: SchemeParams, base: np.ndarray,
                          shift: int) -> np.ndarray:
    """Parallel-class replica shift: rotate the rack by ``shift`` and the
    in-rack slot by ``shift // P`` (distinct servers for shift < K).  The
    primitive behind the structured replica placements of
    :mod:`repro.placement.structured` — each shift is a bijection of the
    base layout, i.e. one parallel class of a resolvable storage design."""
    rack = (base // p.Kr + shift) % p.P
    slot = (base % p.Kr + shift // p.P) % p.Kr
    return rack * p.Kr + slot


# ---------------------------------------------------------------------------
# Map assignment
# ---------------------------------------------------------------------------

def resolvable_assignment(params: SchemeParams,
                          perm: Sequence[int] | None = None) -> Assignment:
    """Resolvable-design map assignment (scheme ``'hybrid_resolvable'``).

    Structural slots are (layer, batch, w), slot-major exactly like the
    binomial family's (layer, subset, w); ``perm`` places subfile
    ``perm[slot]`` into each slot — the same Section-IV locality degree of
    freedom.  ``meta['slot_of_subfile']`` maps each subfile back to its
    slot and ``meta['codewords']`` carries the batch -> codeword table.
    """
    params.validate_hybrid_resolvable()
    p = params
    q, r = p.spc_q, p.r
    cw = spc_codewords(q, r)                              # [B, r]
    B = cw.shape[0]
    M = p.M_res
    n_layer = p.subfiles_per_layer
    if perm is None:
        perm = list(range(p.N))
    if sorted(perm) != list(range(p.N)):
        raise ValueError("perm must be a permutation of range(N)")

    # racks of batch t: class u's member is rack u*q + cw[t, u]
    batch_racks = np.arange(r) * q + cw                   # [B, r]
    servers: List[Optional[Tuple[int, ...]]] = [None] * p.N
    slot_of: List[Optional[Tuple[int, int, int]]] = [None] * p.N
    for layer in range(p.Kr):
        for t in range(B):
            srvs = tuple(sorted(int(rk) * p.Kr + layer
                                for rk in batch_racks[t]))
            for w in range(M):
                slot_index = layer * n_layer + t * M + w
                subfile = perm[slot_index]
                servers[subfile] = srvs
                slot_of[subfile] = (layer, t, w)
    return Assignment("hybrid_resolvable", p, tuple(servers),  # type: ignore[arg-type]
                      meta={"slot_of_subfile": tuple(slot_of),
                            "perm": tuple(perm),
                            "codewords": tuple(map(tuple, cw.tolist()))})


# ---------------------------------------------------------------------------
# Group enumeration shared by the compiler and the message-level schedule
# ---------------------------------------------------------------------------

def shared_groups(p: SchemeParams, sender_rack: int,
                  dest_rack: int) -> np.ndarray:
    """Multicast-group vectors containing both racks, [n, r] in
    lexicographic order of the free coordinates (deterministic — the
    sender's stream layout and the receiver's decode tables enumerate the
    SAME order).  Empty for same-class pairs and for self."""
    q, r = p.spc_q, p.r
    cs, vs = divmod(sender_rack, q)
    cd, vd = divmod(dest_rack, q)
    if cs == cd:
        return np.zeros((0, r), dtype=np.int64)
    free = [t for t in range(r) if t not in (cs, cd)]
    n_free = len(free)
    combos = np.stack(np.meshgrid(*[np.arange(q)] * n_free, indexing="ij"),
                      axis=-1).reshape(-1, n_free) if n_free else \
        np.zeros((1, 0), np.int64)
    g = np.zeros((combos.shape[0], r), dtype=np.int64)
    g[:, cs] = vs
    g[:, cd] = vd
    for k, t in enumerate(free):
        g[:, t] = combos[:, k]
    parity = (g[:, :-1].sum(axis=1) % q) == g[:, -1]      # codeword mask
    return g[~parity]


def max_shared_groups(p: SchemeParams) -> int:
    """Uniform stage-1 stream size: shared-group count of a cross-class
    rack pair — q^{r-2} - q^{r-3} for r >= 3 (codewords with two fixed
    coordinates are q^{r-3}); for r = 2 pairs share at most one group."""
    q, r = p.spc_q, p.r
    if r == 2:
        return 1
    return q ** (r - 2) - (q ** (r - 3) if r >= 3 else 0)


def shared_group_counts(p: SchemeParams) -> np.ndarray:
    """[P, P] actual shared-group counts per (sender, dest) rack pair —
    the unpadded stage-1 stream sizes behind ``plan_transfer_matrices``."""
    q, r = p.spc_q, p.r
    cls = np.arange(p.P) // q
    val = np.arange(p.P) % q
    cross_class = cls[:, None] != cls[None, :]
    if r == 2:
        counts = (cross_class & (val[:, None] != val[None, :])).astype(
            np.int64)
    else:
        counts = cross_class.astype(np.int64) * max_shared_groups(p)
    return counts


# ---------------------------------------------------------------------------
# Plan compiler
# ---------------------------------------------------------------------------

@register_plan_compiler("resolvable")
def compile_resolvable_plan(p: SchemeParams,
                            perm: Tuple[int, ...] | None = None
                            ) -> HybridShufflePlan:
    """Compile the resolvable-design shuffle into executable index tables.

    Same table schema as the binomial compiler (see
    :class:`~repro.core.plan_registry.HybridShufflePlan`); packets carry
    arity = r - 1 components and ``cross_valid`` masks the padded slots of
    same-class (and r = 2 same-value) rack pairs.  Cost is
    O(N + P^2 * q^{r-2} * M) — polynomial in P with exponent set by the
    gain, never a binomial.
    """
    p.validate_hybrid_resolvable()
    q, r = p.spc_q, p.r
    M = p.M_res
    sh = M // (r - 1)
    cw = spc_codewords(q, r)                               # [B, r]
    B = cw.shape[0]
    n_layer = p.subfiles_per_layer
    a = resolvable_assignment(p, perm=list(perm) if perm is not None
                              else None)
    slot = np.asarray(a.meta["slot_of_subfile"], dtype=np.int64)  # [N, 3]

    # subfile id of each structural slot: S[layer, batch, w]
    S = np.empty((p.Kr, B, M), dtype=np.int64)
    S[slot[:, 0], slot[:, 1], slot[:, 2]] = np.arange(p.N)

    # rack-membership over batches: member[i, t] iff rack i maps batch t
    cls = np.arange(p.P) // q
    val = np.arange(p.P) % q
    member = cw[:, cls].T == val[:, None]                  # [P, B]
    n_loc_b = B // q                                       # batches per rack
    ts = np.nonzero(member)[1].reshape(p.P, n_loc_b)       # [P, n_loc_b]
    rank = np.zeros((p.P, B), dtype=np.int64)
    rank[np.arange(p.P)[:, None], ts] = np.arange(n_loc_b)[None, :]

    n_loc = n_loc_b * M
    n_groups = max_shared_groups(p)
    n_send = n_groups * sh

    layer_table = np.broadcast_to(S.reshape(1, p.Kr, n_layer),
                                  (p.P, p.Kr, n_layer))
    local_subfiles = np.ascontiguousarray(
        S[:, ts, :].transpose(1, 0, 2, 3).reshape(p.P, p.Kr, n_loc))
    local_mask = np.broadcast_to(
        np.repeat(member, M, axis=1)[:, None, :], (p.P, p.Kr, n_layer))
    local_pos = np.broadcast_to(
        (ts[:, :, None] * M + np.arange(M)).reshape(p.P, 1, n_loc),
        (p.P, p.Kr, n_loc))

    arity = r - 1
    n_known = arity - 1
    off = np.arange(sh)
    cross_send_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)
    cross_recv_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)
    cross_valid = np.zeros((p.P, p.P, n_send), dtype=bool)
    mcast_comp_pos = np.zeros((p.P, p.P, n_send, arity), dtype=np.int64)
    mcast_comp_rack = np.zeros((p.P, p.P, n_send, arity), dtype=np.int64)
    mcast_known_pos = np.zeros((p.P, p.P, n_send, n_known), dtype=np.int64)
    mcast_known_rack = np.zeros((p.P, p.P, n_send, n_known), dtype=np.int64)

    def sender_pos(u_cls: int, t_cls: int) -> int:
        """Share index of sender class u among receiver t's r-1 senders."""
        return u_cls if u_cls < t_cls else u_cls - 1

    for s_rack in range(p.P):
        cu = s_rack // q
        for z_rack in range(p.P):
            if z_rack == s_rack:
                continue
            ct = z_rack // q
            groups = shared_groups(p, s_rack, z_rack)      # [n_g, r]
            for g_idx, g in enumerate(groups):
                rows = slice(g_idx * sh, (g_idx + 1) * sh)
                # --- dest z's missing batch: the unicast stream -----------
                b_z = needed_batch(g, ct, q)
                t_z = int(batch_index(b_z, q))
                pos_z = sender_pos(cu, ct)
                cross_send_pos[s_rack, :, z_rack, rows] = (
                    rank[s_rack, t_z] * M + pos_z * sh + off)
                cross_recv_pos[z_rack, :, s_rack, rows] = (
                    t_z * M + pos_z * sh + off)
                cross_valid[z_rack, s_rack, rows] = True
                # --- coded packet components (identical for every dest in
                # the group: a true multicast payload) ----------------------
                comp_classes = [t for t in range(r) if t != cu]
                for c, t_cls in enumerate(comp_classes):
                    b_t = needed_batch(g, t_cls, q)
                    t_i = int(batch_index(b_t, q))
                    mcast_comp_pos[s_rack, z_rack, rows, c] = (
                        rank[s_rack, t_i] * M
                        + sender_pos(cu, t_cls) * sh + off)
                    mcast_comp_rack[s_rack, z_rack, rows, c] = (
                        t_cls * q + g[t_cls])
                # --- receiver side information: components for the other
                # members, all batches the receiver itself maps ------------
                known_classes = [t for t in range(r) if t not in (cu, ct)]
                for c, t_cls in enumerate(known_classes):
                    b_t = needed_batch(g, t_cls, q)
                    t_i = int(batch_index(b_t, q))
                    mcast_known_pos[z_rack, s_rack, rows, c] = (
                        rank[z_rack, t_i] * M
                        + sender_pos(cu, t_cls) * sh + off)
                    mcast_known_rack[z_rack, s_rack, rows, c] = (
                        t_cls * q + g[t_cls])

    return HybridShufflePlan(p, local_subfiles, cross_send_pos, layer_table,
                             cross_recv_pos, local_mask, n_send, local_pos,
                             mcast_comp_pos, mcast_comp_rack,
                             mcast_known_pos, mcast_known_rack,
                             family="resolvable", cross_valid=cross_valid)
