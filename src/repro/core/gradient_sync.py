"""Gradient aggregation with Hybrid-Coded-MapReduce structure.

The paper's map-replication + two-stage shuffle maps onto data-parallel
gradient synchronization as follows (racks = slow-tier groups, e.g. TPU pods;
servers = chips):

  * map task            = computing one microbatch-chunk's gradient
  * replication r = 2   = every chunk {a, b} is computed by racks a AND b
  * cross-rack stage    = coded reduce-scatter over the slow axis: each rack
    pre-sums the chunks it *owns* (unique owner per chunk), omitting chunks
    the destination already has — delivering the receive-side optimum
    G * (1 - r/P) cross-rack bytes per rack instead of uncoded G * (1 - 1/P)
  * intra-rack stage    = ordinary fast-axis reduce-scatter / all-gather

Because gradient aggregation is SUM-reducible, the linear-combining function
f(.) of the paper is realized *natively on the wire* (partial sums), and the
same replication yields STRAGGLER TOLERANCE: any single rack's chunks are
recoverable from its pair partners (:func:`coded_reduce_scatter_r2` with
``failed``).

Three pjit-level modes (chosen purely via shardings; see launch/dryrun.py):
  dp_flat       — batch sharded over ('pod','data'); XLA all-reduces over both
  dp_hybrid_r2  — batch replicated over 'pod' (r = P = 2 full map replication
                  across pods): ZERO cross-pod gradient traffic, 2x map FLOPs
                  — the paper's L_cro = QN/r (1 - r/P) = 0 corner, exactly
  fsdp          — params/optimizer sharded over 'data' (ZeRO-3): all-gather /
                  reduce-scatter; composes with either of the above
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------------------
# shard_map-level collectives (manual axes)
# ---------------------------------------------------------------------------

def hierarchical_allreduce(x: jax.Array, fast_axis: str, slow_axis: str,
                           scatter_dim: int = 0) -> jax.Array:
    """Two-stage all-reduce: intra-rack reduce-scatter (fast links), cross-rack
    all-reduce on 1/Kr-sized shards (slow links, all layers in parallel —
    the paper's per-layer decomposition of the cross-rack stage), intra-rack
    all-gather.  Mathematically identical to psum over both axes."""
    x = jax.lax.psum_scatter(x, fast_axis, scatter_dimension=scatter_dim,
                             tiled=True)
    x = jax.lax.psum(x, slow_axis)
    return jax.lax.all_gather(x, fast_axis, axis=scatter_dim, tiled=True)


def _chunk_pairs(P_: int) -> list[tuple[int, int]]:
    return [(a, b) for a in range(P_) for b in range(a + 1, P_)]


def chunk_index_table(P_: int) -> np.ndarray:
    """T[a] = indices (into the pair list) of chunks containing rack a, in
    ascending partner order; shape [P, P-1]."""
    pairs = _chunk_pairs(P_)
    out = np.zeros((P_, P_ - 1), dtype=np.int64)
    for a in range(P_):
        out[a] = [i for i, pr in enumerate(pairs) if a in pr]
    return out


def batch_chunk_for_rack(batch: np.ndarray | jax.Array, P_: int,
                         rack: int) -> list:
    """Split a global batch into C(P,2) chunks and return the P-1 chunks that
    rack `rack` must map (replication r=2).  Host-side helper for the data
    pipeline."""
    pairs = _chunk_pairs(P_)
    n = len(pairs)
    chunks = np.array_split(np.asarray(batch), n)
    return [chunks[i] for i, pr in enumerate(pairs) if rack in pr]


def coded_reduce_scatter_r2(chunk_grads: jax.Array, axis: str,
                            P_: int, failed: int | None = None,
                            combine_impl: str = "xla") -> jax.Array:
    """Cross-rack stage of hybrid-coded gradient sync (r = 2).

    chunk_grads: [P-1, G] — this rack's per-chunk gradient partials, rows
      ordered by ascending partner rack (see :func:`chunk_index_table`).
      G must be divisible by P.
    Returns [G/P]: this rack's shard of the TOTAL gradient sum over all
      C(P,2) chunks (each chunk counted exactly once).

    Cross-rack bytes per rack: (P-1) * (P-2)/(P-1) * G/P = G (1 - 2/P),
    the receive-side optimum with r = 2 — vs uncoded G (1 - 1/P).

    ``failed``: id of a straggling/failed rack whose transmissions are lost.
    Ownership of its chunks transparently falls back to the partner rack, so
    the result is STILL the exact full-batch gradient (r=2 erasure tolerance).
    The failed rack's own return value is garbage; survivors are exact.

    ``combine_impl``: implementation of the per-destination linear combining
    f(.) that builds each send block — ``'xla'`` (einsum) or ``'pallas'``
    (the fused :mod:`repro.kernels.coded_combine` encode kernel; falls back
    to interpret mode off TPU).
    """
    if combine_impl not in ("xla", "pallas"):
        raise ValueError(f"combine_impl must be 'xla' or 'pallas', "
                         f"got {combine_impl!r}")
    me = jax.lax.axis_index(axis)
    G = chunk_grads.shape[-1]
    assert G % P_ == 0, (G, P_)
    shard = G // P_
    partners = _partners_matrix(P_)            # [P, P-1] partner of each row

    part = jnp.asarray(partners)[me]           # [P-1] partner rack per chunk
    # ownership: chunk {a,b} owned by min(a,b); if owner failed, partner owns
    own = part > me
    if failed is not None:
        own = jnp.where(part == failed, me != failed, own)
        own = jnp.where(me == failed, False, own)

    # send buffer: for each destination z, sum of my OWNED chunks not
    # containing z, restricted to z's shard — the paper's f(.) with 0/1
    # coefficients (a partial sum the destination cannot form itself).
    x = chunk_grads.reshape(P_ - 1, P_, shard)          # split into shards
    if combine_impl == "pallas":
        from ..kernels.coded_combine import ops as cc_ops
        # coefficients vary per destination, payloads are shard-sized rows:
        # one fused encode per destination (P_ is small and static)
        sends = jnp.stack([
            cc_ops.coded_encode(
                [x[c, z, :] for c in range(P_ - 1)],
                (own & (part != z)).astype(jnp.float32), block_t=8)
            for z in range(P_)])                         # [P, shard]
    else:
        def block_for(z):
            sel = own & (part != z)                      # [P-1]
            return jnp.einsum("c,cs->s", sel.astype(x.dtype), x[:, z, :])
        sends = jax.vmap(block_for)(jnp.arange(P_))      # [P, shard]
    recvd = jax.lax.all_to_all(sends, axis, split_axis=0, concat_axis=0,
                               tiled=True)               # [P, shard]
    if failed is not None:
        recvd = recvd * (jnp.arange(P_) != failed).astype(recvd.dtype)[:, None]
    far = recvd.sum(axis=0) - recvd[me]                  # exclude self slot
    # local part: ALL chunks containing me, each counted once (I am in them)
    local = x[:, :, :].sum(axis=0)[me]                   # sum over my chunks
    return far + local


def _partners_matrix(P_: int) -> np.ndarray:
    pairs = _chunk_pairs(P_)
    out = np.zeros((P_, P_ - 1), dtype=np.int64)
    for a in range(P_):
        out[a] = [pr[0] if pr[1] == a else pr[1]
                  for pr in pairs if a in pr]
    return out


def uncoded_reduce_scatter(grad: jax.Array, axis: str, P_: int) -> jax.Array:
    """Baseline: plain reduce-scatter of a full local gradient [G] -> [G/P]."""
    return jax.lax.psum_scatter(grad, axis, scatter_dimension=0, tiled=True)


# ---------------------------------------------------------------------------
# pjit-level sharding policies (used by trainer / dryrun)
# ---------------------------------------------------------------------------

DP_MODES = ("dp_flat", "dp_hybrid_r2")


def batch_pspec(mode: str, multi_pod: bool) -> P:
    """PartitionSpec of the token batch under a DP sync mode.

    dp_flat      — shard over every data-parallel axis.
    dp_hybrid_r2 — replicate over 'pod' (the paper's map replication with
                   r = P: every pod maps every chunk => zero cross-pod
                   shuffle), shard over 'data' only.
    """
    if mode not in DP_MODES:
        raise ValueError(f"unknown DP mode {mode}")
    if not multi_pod:
        return P("data")
    return P(("pod", "data")) if mode == "dp_flat" else P("data")


def grad_sync_cost(G_bytes: float, P_: int, r: int, mode: str) -> dict:
    """Analytic slow-tier byte cost per rack of one gradient sync (for the
    roofline's collective term and EXPERIMENTS.md).  Receive-side accounting,
    point-to-point links."""
    if mode == "uncoded":
        rs = G_bytes * (1 - 1 / P_)
    elif mode == "coded_r":
        rs = G_bytes * (1 - r / P_)
    elif mode == "full_replication":
        rs = 0.0
    else:
        raise ValueError(mode)
    return {"cross_rack_bytes_per_rack": rs,
            "map_flops_multiplier": {"uncoded": 1, "coded_r": r,
                                     "full_replication": P_}[mode]}
