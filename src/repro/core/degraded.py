"""Degraded-mode plan recompilation: shuffle around crashed servers.

The paper pays for r-fold map replication as a communication code, but the
same redundancy is an *erasure* code: every layer-table row has r owner
racks, so losing up to r - 1 owners per multicast group leaves the shuffle
decodable WITHOUT re-running map.  :func:`compile_degraded_plan` turns that
observation into executable index tables, for ANY registered plan family —
it reasons only over the base plan's schema (``local_mask`` names the
owners, ``cross_send_pos``/``cross_recv_pos``/``cross_valid`` name the
original routing), never over family internals.

Failure model (matches :mod:`repro.mapreduce.recovery` and the sim's crash
events): the failure unit is one server — mesh coordinate (rack i, layer j),
flat id ``i * Kr + j``.  A crash loses the server's IN-MEMORY map outputs; a
replacement worker rejoins at the same coordinate with empty memory, so the
collective keeps all K participants and the failed coordinates contribute
zeros (tests poison them with garbage to prove no information flows out).

Construction, per layer j (layers fail independently — rack i failing in
layer j says nothing about layer j'):

  * every surviving receiver still needs its non-local rows; a replaced
    receiver needs ALL rows (its local copies died with it);
  * a needed row keeps its ORIGINAL source when that sender survived
    (the base plan's load balance is preserved); rows whose sender died —
    and the replaced receivers' own rows — are re-sourced from the lowest-
    numbered surviving owner rack;
  * rows with NO surviving owner are *orphans*: reported per subfile id so
    the engine can re-map exactly those on survivors and inject them via the
    ``patch`` argument of
    :func:`repro.core.coded_collectives.shuffle_device_body`.

The degraded tables keep the base schema, with two deltas:

  * ``cross_valid`` gains a layer axis — [P, Kr, P, n_send] — because
    repair streams differ per layer (both the device body and the NumPy
    oracle dispatch on ``ndim``);
  * the multicast tables are emptied to arity 1: degraded stage 1 runs
    UNICAST.  Replaced receivers have no side information to decode with,
    and a survivor's repair read is a raw replica row, so coded packets
    would not cover the repairs anyway.  Decode tables of the failure-free
    plan (and the Pallas ``coded_combine`` path) are untouched.

Cache hygiene: degraded plans live in a BOUNDED side LRU keyed
``(params, perm, family, failed)`` — an injected-failure sweep cannot evict
the hot failure-free plans from the main cache of
:mod:`repro.core.coded_collectives` (see :func:`degraded_cache_info`).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from collections import OrderedDict
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from .params import SchemeParams
from .plan_registry import HybridShufflePlan, family_of_scheme
from .shuffle_plan import StageTraffic


# ---------------------------------------------------------------------------
# The degraded plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class DegradedPlan:
    """A base plan re-routed around ``failed`` servers.

    ``plan`` is a full :class:`HybridShufflePlan` (same schema as the base;
    4-dim ``cross_valid``, arity-1 multicast tables) — every consumer of the
    base schema runs it unchanged.  ``orphan_rows[j]`` lists layer-j rows
    with no surviving owner; ``orphan_subfiles`` the matching global subfile
    ids (what the engine must re-map).  ``n_repaired_rows`` counts
    (receiver, row) deliveries that had to be re-sourced vs the base routing
    — the repair traffic beyond the failure-free unicast schedule.
    """
    base: HybridShufflePlan
    failed: Tuple[int, ...]
    plan: HybridShufflePlan
    orphan_rows: Tuple[np.ndarray, ...]        # per layer j
    orphan_subfiles: np.ndarray                # sorted global subfile ids
    n_repaired_rows: int

    @property
    def params(self) -> SchemeParams:
        return self.base.params

    @property
    def decode_around(self) -> bool:
        """True when every lost row keeps a surviving owner — recovery needs
        zero re-mapped subfiles (the f <= r-1 per-group guarantee)."""
        return self.orphan_subfiles.size == 0

    def transfer_loads(self) -> Dict[str, np.ndarray]:
        """Exact wire loads of the degraded shuffle, in <key, value> pairs
        (the shape of :func:`~repro.core.coded_collectives
        .plan_transfer_matrices`): ``cross_rack_matrix[src, dst]`` stage-1
        root-switch pairs (unicast — the multicast gain is forfeited during
        recovery) and ``intra_per_rack`` stage-2 ToR pairs (unchanged from
        the failure-free plan: stage 2 is a per-server key split of full
        layer tables).  Delegates to ``plan_transfer_matrices``, which
        dispatches on the degraded 4-dim ``cross_valid`` schema."""
        from .coded_collectives import plan_transfer_matrices
        return plan_transfer_matrices(self.plan, multicast="unicast")


def _failed_mask(p: SchemeParams, failed: Sequence[int]) -> np.ndarray:
    """[P, Kr] bool from flat failed server ids, validated."""
    mask = np.zeros((p.P, p.Kr), dtype=bool)
    for s in failed:
        s = int(s)
        if not 0 <= s < p.K:
            raise ValueError(f"failed server id {s} out of range [0, {p.K})")
        mask[s // p.Kr, s % p.Kr] = True
    return mask


def _compile_degraded(p: SchemeParams, failed: Tuple[int, ...], family: str,
                      perm: Optional[Tuple[int, ...]]) -> DegradedPlan:
    """Uncached construction (see module docstring for the algorithm)."""
    from .coded_collectives import compile_hybrid_plan
    base = compile_hybrid_plan(p, perm=perm, family=family)
    P_, Kr = p.P, p.Kr
    n_layer = p.subfiles_per_layer
    fail_rl = _failed_mask(p, failed)
    if fail_rl.all() and failed:
        raise ValueError("all servers failed; nothing to recover from")

    # per-layer (receiver, source) -> sorted needed rows
    streams: List[List[List[np.ndarray]]] = []   # [Kr][P recv][P src] rows
    orphan_rows: List[np.ndarray] = []
    n_repaired = 0
    local_mask = np.asarray(base.local_mask)
    for j in range(Kr):
        fail_j = fail_rl[:, j]                              # [P]
        owners = local_mask[:, j, :]                        # [P, n_layer]
        alive_owner = owners & ~fail_j[:, None]
        # original stage-1 source of each (receiver, row); -1 = local/none
        src0 = np.full((P_, n_layer), -1, dtype=np.int64)
        if base.n_send:
            for i in range(P_):
                for z in range(P_):
                    if z == i:
                        continue
                    cv = base.cross_valid
                    valid = (slice(None) if cv is None else
                             cv[i, j, z] if cv.ndim == 4 else cv[i, z])
                    src0[i, base.cross_recv_pos[i, j, z][valid]] = z
        # needed rows per receiver: non-local ones, plus ALL rows of a
        # replaced receiver (its local copies died with the crash)
        first_alive = np.where(alive_owner.any(axis=0),
                               alive_owner.argmax(axis=0), -1)  # [n_layer]
        orphan = ~alive_owner.any(axis=0)
        orphan_rows.append(np.nonzero(orphan)[0])
        per_recv: List[List[np.ndarray]] = []
        for i in range(P_):
            need = (~owners[i]) | fail_j[i]
            keep = src0[i] >= 0
            keep &= np.where(keep, ~fail_j[np.clip(src0[i], 0, P_ - 1)],
                             False)
            src = np.where(need & keep, src0[i], -1)
            repair = need & ~keep & ~orphan
            src = np.where(repair, first_alive, src)
            n_repaired += int(repair.sum())
            per_recv.append([np.nonzero(src == z)[0] for z in range(P_)])
        streams.append(per_recv)

    n_send = max((len(rows) for per_recv in streams
                  for by_src in per_recv for rows in by_src), default=0)
    send_pos = np.zeros((P_, Kr, P_, n_send), dtype=np.int64)
    recv_pos = np.zeros((P_, Kr, P_, n_send), dtype=np.int64)
    valid = np.zeros((P_, Kr, P_, n_send), dtype=bool)
    local_pos = np.asarray(base.local_pos)
    for j in range(Kr):
        # sender z's layer-row -> local val-row inverse, per layer
        inv = np.full((P_, n_layer), 0, dtype=np.int64)
        for z in range(P_):
            inv[z, local_pos[z, j]] = np.arange(local_pos.shape[-1])
        for i in range(P_):
            for z in range(P_):
                rows = streams[j][i][z]
                k = len(rows)
                if k == 0:
                    continue
                recv_pos[i, j, z, :k] = rows
                send_pos[z, j, i, :k] = inv[z, rows]
                valid[i, j, z, :k] = True

    # arity-1 multicast tables: degraded stage 1 is unicast by construction
    # (mcast_arity == 1 makes every coded branch degenerate)
    mcast_shape = (P_, P_, n_send, 1)
    plan = HybridShufflePlan(
        p, base.local_subfiles, send_pos, base.layer_subfiles, recv_pos,
        base.local_mask, n_send, base.local_pos,
        np.zeros(mcast_shape, dtype=np.int64),
        np.zeros(mcast_shape, dtype=np.int64),
        np.zeros((P_, P_, n_send, 0), dtype=np.int64),
        np.zeros((P_, P_, n_send, 0), dtype=np.int64),
        family=base.family, cross_valid=valid)
    layer_sub = np.asarray(base.layer_subfiles)
    orphan_subs = np.unique(np.concatenate(
        [layer_sub[0, j, rows] for j, rows in enumerate(orphan_rows)]
    )) if any(len(r) for r in orphan_rows) else np.empty(0, dtype=np.int64)
    return DegradedPlan(base, failed, plan, tuple(orphan_rows),
                        orphan_subs, n_repaired)


# ---------------------------------------------------------------------------
# Bounded side cache (keeps failure sweeps out of the hot plan cache)
# ---------------------------------------------------------------------------

DEGRADED_CACHE_MAXSIZE_ENV = "REPRO_DEGRADED_CACHE_MAXSIZE"
_DEGRADED_CACHE_DEFAULT_MAXSIZE = 32


class DegradedCacheInfo(NamedTuple):
    """Stats of the degraded-plan side cache; ``evictions`` counts entries
    dropped by the LRU bound (the failure-sweep pressure the main plan
    cache is shielded from)."""
    hits: int
    misses: int
    maxsize: Optional[int]
    currsize: int
    evictions: int


class _BoundedLRU:
    """Tiny OrderedDict LRU with explicit hit/miss/eviction counters
    (functools.lru_cache hides evictions)."""

    def __init__(self, maxsize: Optional[int]) -> None:
        self.maxsize = maxsize
        self._data: "OrderedDict[tuple, object]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = self.misses = self.evictions = 0

    def get_or(self, key: tuple, mk: Callable[[], object]) -> object:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
        value = mk()                       # compile outside the lock
        with self._lock:
            if key in self._data:          # racing compile: keep the first
                self._data.move_to_end(key)
                return self._data[key]
            self.misses += 1
            self._data[key] = value
            if self.maxsize is not None and len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1
        return value

    def info(self) -> DegradedCacheInfo:
        return DegradedCacheInfo(self.hits, self.misses, self.maxsize,
                                 len(self._data), self.evictions)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = self.misses = self.evictions = 0


def _degraded_cache_default_maxsize() -> int:
    raw = os.environ.get(DEGRADED_CACHE_MAXSIZE_ENV, "")
    try:
        return int(raw)
    except ValueError:
        return _DEGRADED_CACHE_DEFAULT_MAXSIZE


def configure_degraded_cache(maxsize: Optional[int] = None) -> None:
    """(Re)build the degraded-plan side cache (``None`` -> the
    ``REPRO_DEGRADED_CACHE_MAXSIZE`` env var, falling back to 32); drops all
    cached degraded plans and zeroes the counters."""
    global _DEGRADED_CACHE
    if maxsize is None:
        maxsize = _degraded_cache_default_maxsize()
    _DEGRADED_CACHE = _BoundedLRU(maxsize)


_DEGRADED_CACHE = _BoundedLRU(_degraded_cache_default_maxsize())


def degraded_cache_info() -> DegradedCacheInfo:
    return _DEGRADED_CACHE.info()


def degraded_cache_clear() -> None:
    _DEGRADED_CACHE.clear()


def compile_degraded_plan(p: SchemeParams, failed: Sequence[int],
                          family: str = "binomial",
                          perm: Sequence[int] | None = None) -> DegradedPlan:
    """Compile the degraded routing of ``(p, perm, family)`` around the
    ``failed`` flat server ids (order/duplicates ignored).

    Family-agnostic: works for every registered plan family through the base
    plan's schema alone.  Results are memoized in a bounded side LRU keyed
    ``(params, perm, family, failed)`` — repeated recoveries of one failure
    set are O(1), and failure sweeps cannot evict hot failure-free plans
    (those live in the main cache of :mod:`repro.core.coded_collectives`).
    An empty ``failed`` is allowed and yields repair-free tables equivalent
    to the base routing (the engine skips degraded execution in that case).
    """
    failed_t = tuple(sorted({int(s) for s in failed}))
    key_perm = None if perm is None else tuple(int(x) for x in perm)
    key = (p, key_perm, family, failed_t)
    return _DEGRADED_CACHE.get_or(
        key, lambda: _compile_degraded(p, failed_t, family, key_perm))


# ---------------------------------------------------------------------------
# Patch construction (orphan re-map injection)
# ---------------------------------------------------------------------------

def build_patch(dplan: DegradedPlan, orphan_values: np.ndarray) -> np.ndarray:
    """Per-device stage-1 patch from re-mapped orphan values.

    ``orphan_values[m]`` is the [Q, d] map output of subfile
    ``dplan.orphan_subfiles[m]`` (recomputed on survivors).  Returns
    [K, n_layer, q_rack, d]: device (i, j)'s layer table gets its rack's key
    block of every orphan row added AFTER local fill and repair receives
    (orphan rows receive nothing and their local fill is zeros, so add ==
    set).  Zero rows everywhere else."""
    p = dplan.params
    q_rack = p.Q // p.P
    n_layer = p.subfiles_per_layer
    d = orphan_values.shape[-1] if orphan_values.ndim == 3 else 1
    dtype = orphan_values.dtype if orphan_values.size else np.float32
    patch = np.zeros((p.K, n_layer, q_rack, d), dtype=dtype)
    if not dplan.orphan_subfiles.size:
        return patch
    index = {int(sf): m for m, sf in enumerate(dplan.orphan_subfiles)}
    layer_sub = np.asarray(dplan.base.layer_subfiles)
    for j, rows in enumerate(dplan.orphan_rows):
        for t in rows:
            v = orphan_values[index[int(layer_sub[0, j, t])]]   # [Q, d]
            for i in range(p.P):
                patch[p.server_id(i, j), t] = v[i * q_rack:(i + 1) * q_rack]
    return patch


# ---------------------------------------------------------------------------
# Stage-traffic export for the simulator / chooser
# ---------------------------------------------------------------------------

def degraded_stage_traffic(p: SchemeParams, scheme: str,
                           failed: Sequence[int]
                           ) -> Tuple[List[StageTraffic], int]:
    """(degraded shuffle stages, re-mapped subfile count) of recovering
    ``scheme`` after losing ``failed`` servers — the load the sim's crash
    events and the chooser's availability term price.

    Hybrid families compile the EXACT degraded plan when the instance is
    executable (the simulated recovery traffic IS the degraded schedule);
    orphaned subfiles additionally pay a one-per-rack redistribution of
    their re-mapped values (``n_orphans * Q`` cross pairs — the engine
    injects them host-side, a real cluster broadcasts them).  Instances the
    compiler rejects (Table-I rows simulated with ``check=False``) and the
    non-hybrid schemes fall back to a closed-form model: the re-run forfeits
    the multicast gain (cross x arity), each failed server's replacement
    re-receives its n_loc local rows (``f * (rN/K) * (Q/P)`` cross pairs),
    and r = 1 schemes re-map the dead servers' full partitions — the paper's
    erasure-code reading of r, priced as a failure-tolerance knob.
    """
    from .shuffle_plan import scheme_stage_traffic
    failed_t = tuple(sorted({int(s) for s in failed}))
    f = len(failed_t)
    family = family_of_scheme(scheme)
    if family is not None:
        try:
            dp = compile_degraded_plan(p, failed_t, family=family)
            tm = dp.transfer_loads()
            n_remap = int(dp.orphan_subfiles.size)
            cross = float(tm["cross_rack_matrix"].sum()) + n_remap * p.Q
            zeros = tuple(0.0 for _ in range(p.P))
            stages = [StageTraffic("cross", cross, zeros),
                      StageTraffic("intra", 0.0,
                                   tuple(float(x)
                                         for x in tm["intra_per_rack"]))]
            return stages, n_remap
        except ValueError:
            pass
    base = scheme_stage_traffic(p, scheme, check=False)
    repl = 1 if scheme == "uncoded" else p.r
    gain = {"binomial": p.r, "resolvable": p.r - 1}.get(family or "", p.r) \
        if scheme != "uncoded" else 1
    gain = max(int(gain), 1)
    n_remap = (f * p.N) // p.K if repl == 1 else 0
    repair = f * (repl * p.N / p.K) * (p.Q / p.P) + n_remap * p.Q
    stages = []
    for st in base:
        if st.stage == "cross":
            stages.append(StageTraffic("cross",
                                       st.cross_pairs * gain + repair,
                                       st.intra_pairs_per_rack))
        else:
            stages.append(st)
    return stages, int(n_remap)


__all__ = [
    "DegradedPlan", "compile_degraded_plan", "build_patch",
    "degraded_stage_traffic", "degraded_cache_info", "degraded_cache_clear",
    "configure_degraded_cache", "DegradedCacheInfo",
    "DEGRADED_CACHE_MAXSIZE_ENV",
]
