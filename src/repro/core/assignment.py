"""Map-task (subfile -> server set) assignment designs.

Three designs from the paper:

  * uncoded — each subfile mapped exactly once; server s gets the s-th block
    of N/K subfiles.
  * coded   — Coded MapReduce [Li-Maddah-Ali-Avestimehr]: each r-subset of the
    K servers is assigned J = N / C(K, r) unique subfiles.
  * hybrid  — the paper's scheme: subfiles are split into Kr layers of NP/K;
    within layer j, each r-subset T of the P racks gets M unique subfiles,
    mapped at servers {S_{t j} : t in T} (replication across racks only).

An assignment is represented as

  ``Assignment(scheme, params, servers_of_subfile, meta)``

where ``servers_of_subfile[i]`` is the sorted tuple of flat server ids that
map subfile i.  For the hybrid scheme, ``meta['slot_of_subfile'][i]`` gives
the structural slot (layer, rack_subset_index, w) of subfile i, and a
*permutation* of subfiles over slots yields every other valid hybrid
assignment (the degree of freedom exploited by the Section-IV locality
optimizer).
"""
from __future__ import annotations

import dataclasses
import itertools
from math import comb
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .params import SchemeParams


@dataclasses.dataclass(frozen=True)
class Assignment:
    scheme: str                                   # 'uncoded' | 'coded' | 'hybrid'
    params: SchemeParams
    servers_of_subfile: Tuple[Tuple[int, ...], ...]
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def incidence(self) -> np.ndarray:
        """X[i, s] = 1 iff subfile i is mapped at server s  ([N, K] int64).

        Every derived per-server quantity (:attr:`subfiles_of_server`,
        :meth:`map_load`, :func:`pair_common_counts`) is one vectorized
        reduction of this matrix.
        """
        X = np.zeros((self.params.N, self.params.K), dtype=np.int64)
        srv = np.asarray(self.servers_of_subfile, dtype=np.int64)  # [N, r]
        X[np.arange(self.params.N)[:, None], srv] = 1
        return X

    @property
    def subfiles_of_server(self) -> List[List[int]]:
        X = self.incidence()
        return [np.nonzero(X[:, s])[0].tolist() for s in range(self.params.K)]

    def map_load(self) -> np.ndarray:
        """Number of map tasks executed at each server."""
        return self.incidence().sum(axis=0)

    def rack_load(self) -> np.ndarray:
        """Number of map tasks executed in each rack ([P] int64)."""
        per_server = self.map_load()
        return per_server.reshape(self.params.P, self.params.Kr).sum(axis=1)


# ---------------------------------------------------------------------------
# Structural enumerations
# ---------------------------------------------------------------------------

def rack_subsets(P: int, r: int) -> List[Tuple[int, ...]]:
    """All r-subsets of the P racks, in deterministic (lexicographic) order."""
    return list(itertools.combinations(range(P), r))


def hybrid_slots(params: SchemeParams) -> List[Tuple[int, int, int]]:
    """All (layer, rack_subset_index, w) slots of the hybrid design.

    One slot per subfile; slot order is the canonical subfile order used by
    :func:`hybrid_assignment` when ``perm`` is None.
    """
    params.validate_hybrid()
    slots = []
    n_subsets = comb(params.P, params.r)
    for layer in range(params.n_layers):
        for t_idx in range(n_subsets):
            for w in range(params.M):
                slots.append((layer, t_idx, w))
    return slots


def hybrid_group_of_slot(params: SchemeParams) -> np.ndarray:
    """Group index of every structural slot ([N] int64): slot s belongs to
    (layer, rack-subset) group s // M — :func:`hybrid_slots` is group-major
    with M slots per group.  The basic index map shared by every Section-IV
    objective and solver (:mod:`repro.placement`)."""
    return np.arange(params.N, dtype=np.int64) // params.M


def slot_servers(params: SchemeParams, layer: int, t_idx: int) -> Tuple[int, ...]:
    """Servers mapping the subfiles of slot (layer, t_idx, *)."""
    T = rack_subsets(params.P, params.r)[t_idx]
    return tuple(params.server_id(rack, layer) for rack in T)


# ---------------------------------------------------------------------------
# Assignment constructors
# ---------------------------------------------------------------------------

def uncoded_assignment(params: SchemeParams) -> Assignment:
    params.validate_uncoded()
    per = params.N // params.K
    servers = tuple((i // per,) for i in range(params.N))
    return Assignment("uncoded", params, servers)


def coded_assignment(params: SchemeParams) -> Assignment:
    params.validate_coded()
    subsets = list(itertools.combinations(range(params.K), params.r))
    J = params.J
    servers: List[Tuple[int, ...]] = []
    subset_of_subfile: List[int] = []
    for t_idx, T in enumerate(subsets):
        for _ in range(J):
            servers.append(tuple(T))
            subset_of_subfile.append(t_idx)
    assert len(servers) == params.N
    return Assignment("coded", params, tuple(servers),
                      meta={"subset_of_subfile": tuple(subset_of_subfile)})


def hybrid_assignment(params: SchemeParams,
                      perm: Sequence[int] | None = None) -> Assignment:
    """Hybrid Coded MapReduce assignment.

    ``perm`` is a permutation of range(N): subfile ``perm[slot_index]`` is
    placed into the slot with that index (identity if None).  Any permutation
    yields a valid hybrid scheme — this is the locality-optimization degree of
    freedom of Section IV.
    """
    params.validate_hybrid()
    slots = hybrid_slots(params)
    if perm is None:
        perm = list(range(params.N))
    if sorted(perm) != list(range(params.N)):
        raise ValueError("perm must be a permutation of range(N)")

    servers: List[Tuple[int, ...] | None] = [None] * params.N
    slot_of_subfile: List[Tuple[int, int, int] | None] = [None] * params.N
    for slot_index, (layer, t_idx, w) in enumerate(slots):
        subfile = perm[slot_index]
        servers[subfile] = slot_servers(params, layer, t_idx)
        slot_of_subfile[subfile] = (layer, t_idx, w)
    return Assignment("hybrid", params, tuple(servers),  # type: ignore[arg-type]
                      meta={"slot_of_subfile": tuple(slot_of_subfile),
                            "perm": tuple(perm)})


# ---------------------------------------------------------------------------
# Validation of the structural constraints (Theorem IV.1, conditions 1-4)
# ---------------------------------------------------------------------------

def pair_common_counts(assignment: Assignment) -> np.ndarray:
    """C[j, k] = number of subfiles mapped at both servers j and k."""
    X = assignment.incidence()
    common = X.T @ X
    np.fill_diagonal(common, 0)
    return common


def check_hybrid_constraints(assignment: Assignment) -> None:
    """Assert Theorem IV.1's four constraints hold for a hybrid assignment.

    All four checks are NumPy broadcasts over the pair-common-count matrix —
    no Python loops over server pairs/triples (the transitivity check used to
    be an O(K^3) nested loop).
    """
    p = assignment.params
    common = pair_common_counts(assignment)
    K, M = p.K, p.M
    Y = (common > 0).astype(np.int64)
    offdiag = ~np.eye(K, dtype=bool)
    racks = np.arange(K) // p.Kr

    # (1) no common files within a rack
    same_rack = (racks[:, None] == racks[None, :]) & offdiag
    bad = same_rack & (common != 0)
    assert not bad.any(), np.argwhere(bad)[:1]
    # (2) any pair of servers shares 0 or exactly M subfiles  (r = 2 reading;
    #     for general r the common count over a co-assigned pair is a multiple
    #     of M given by the number of r-subsets containing both racks)
    expected = M * comb(p.P - 2, p.r - 2) if p.r >= 2 else 0
    bad = offdiag & ~np.isin(common, (0, expected))
    assert not bad.any(), (np.argwhere(bad)[:1], expected)
    # (3) degree: each server shares files with exactly (P-1)*[structure] peers
    #     (for r=2 this is P-1; generally the other r-subset members across
    #      all subsets containing the server's rack collapse to the P-1 other
    #      layer members)
    if p.r >= 2:
        deg = Y.sum(axis=1)
        assert (deg == p.P - 1).all(), deg
    # (4) transitivity within a layer: no distinct triple with exactly two
    #     sharing pairs.  Ysum[i, j, k] = Y[i,j] + Y[j,k] + Y[i,k] broadcast.
    Ysum = Y[:, :, None] + Y[None, :, :] + Y[:, None, :]
    idx = np.arange(K)
    distinct = ((idx[:, None, None] != idx[None, :, None])
                & (idx[None, :, None] != idx[None, None, :])
                & (idx[:, None, None] != idx[None, None, :]))
    bad = distinct & (Ysum == 2)
    assert not bad.any(), np.argwhere(bad)[:1]
