"""Closed-form communication costs (Propositions 1-2, Theorem III.1, Cor III.2).

All costs are counted in <key, value> pair transfers, exactly as in the paper.
``intra`` = pairs through a Top-of-Rack switch, ``cro`` = pairs through the
root switch.  A coded multicast counts ONCE regardless of receiver count.
"""
from __future__ import annotations

import dataclasses
from math import comb, e
from typing import Dict

from .params import SchemeParams


@dataclasses.dataclass(frozen=True)
class CommCost:
    intra: float
    cross: float

    @property
    def total(self) -> float:
        return self.intra + self.cross

    def weighted_time(self, intra_bw: float, cross_bw: float) -> float:
        """Shuffle time proxy: pairs / bandwidth per tier (cross is the
        bottleneck tier in a server-rack network; intra transfers of distinct
        racks run in parallel, hence the per-rack divisor)."""
        return self.cross / cross_bw + self.intra / intra_bw


def uncoded_cost(p: SchemeParams, check: bool = True) -> CommCost:
    """Proposition 1."""
    if check:
        p.validate_uncoded()
    intra = p.Q * p.N * (1.0 / p.P - 1.0 / p.K)
    cross = p.Q * p.N * (1.0 - 1.0 / p.P)
    return CommCost(intra, cross)


def coded_cost(p: SchemeParams, check: bool = True) -> CommCost:
    """Proposition 2."""
    if check:
        p.validate_coded()
    total = p.Q * p.N / p.r * (1.0 - p.r / p.K)
    if p.Kr >= p.r + 1:
        frac_intra = p.P * comb(p.Kr, p.r + 1) / comb(p.K, p.r + 1)
    else:
        frac_intra = 0.0
    return CommCost(total * frac_intra, total * (1.0 - frac_intra))


def hybrid_cost(p: SchemeParams, check: bool = True) -> CommCost:
    """Theorem III.1.

    Note: paper Table I row (20,4,20,380,2) violates the theorem's own
    divisibility hypothesis C(P,r)|(NP/K) (=76/6); pass ``check=False`` to
    evaluate the closed form anyway, as the paper implicitly did.
    """
    if check:
        p.validate_hybrid()
    cross = p.Q * p.N / p.r * (1.0 - p.r / p.P)
    intra = p.Q * p.N * (1.0 - p.P / p.K)
    return CommCost(intra, cross)


def hybrid_resolvable_cost(p: SchemeParams, check: bool = True) -> CommCost:
    """Resolvable-design hybrid (repro.core.resolvable): multicast gain r-1
    instead of r, identical intra-rack stage.  Derivation: per layer the
    q^{r-1}(q-1) non-codeword groups each carry r senders' M/(r-1)-row
    packet streams of Q/P keys, each traversing the root once; summed over
    Kr layers this telescopes to QN/(r-1) * (1 - r/P).  Proven against the
    enumerated message schedule in tests/test_resolvable.py."""
    if check:
        p.validate_hybrid_resolvable()
    cross = p.Q * p.N / (p.r - 1) * (1.0 - p.r / p.P)
    intra = p.Q * p.N * (1.0 - p.P / p.K)
    return CommCost(intra, cross)


def cost_table(p: SchemeParams, check: bool = True) -> Dict[str, CommCost]:
    return {
        "uncoded": uncoded_cost(p, check),
        "coded": coded_cost(p, check),
        "hybrid": hybrid_cost(p, check),
    }


# -- Corollary III.2 bounds ---------------------------------------------------

def corollary_bounds(p: SchemeParams) -> Dict[str, float]:
    """Bounds of Corollary III.2 (sanity-checked against exact ratios)."""
    cod, hyb = coded_cost(p), hybrid_cost(p)
    lower_cross_ratio = ((1.0 - p.r / p.K) / (1.0 - p.r / p.P)
                         * (1.0 - e ** (p.r + 1) / p.P ** p.r))
    upper_intra_ratio = (p.r * (p.K - p.P) / (p.K - p.r)
                         * e ** (p.r + 1) * p.P ** p.r)
    out = {
        "cross_ratio_exact": cod.cross / hyb.cross if hyb.cross else float("inf"),
        "cross_ratio_lower_bound": lower_cross_ratio,
        "intra_ratio_exact": hyb.intra / cod.intra if cod.intra else float("inf"),
        "intra_ratio_upper_bound": upper_intra_ratio,
    }
    return out
