"""Scheme parameters for (Hybrid) Coded MapReduce on a server-rack cluster.

Notation follows the paper (Gupta & Lalitha, 2017):
  K  — number of servers in the cluster
  P  — number of racks                  (P | K)
  Kr — servers per rack, Kr = K / P
  N  — number of subfiles of the job
  Q  — number of keys to reduce         (K | Q)
  r  — Map-task replication factor
  M  — subfiles per (layer, rack r-subset) slot in the hybrid scheme,
       M = (N P / K) / C(P, r)
  r_f — file (storage) replication factor, used only by the locality
       optimizer of Section IV (HDFS-style replica placement).

Server indexing: the paper writes S_{ij} with rack 1<=i<=P and in-rack slot
1<=j<=Kr.  We use 0-based flat ids  s = rack * Kr + slot,  and call the set
{S_{1j},...,S_{Pj}} (fixed slot j across racks) a *layer*.
"""
from __future__ import annotations

import dataclasses
from math import comb


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class SchemeParams:
    """Parameters of a MapReduce job on a server-rack cluster."""

    K: int          # servers
    P: int          # racks
    Q: int          # keys
    N: int          # subfiles
    r: int = 2      # map replication factor
    r_f: int = 3    # file replication (locality optimizer only)

    def __post_init__(self) -> None:
        _check(self.K >= 1 and self.P >= 1 and self.Q >= 1 and self.N >= 1,
               "K, P, Q, N must be positive")
        _check(self.K % self.P == 0, f"P={self.P} must divide K={self.K}")
        _check(1 <= self.r, f"replication r={self.r} must be >= 1")
        _check(self.r_f >= 1, "r_f must be >= 1")

    # ---- derived quantities -------------------------------------------------

    @property
    def Kr(self) -> int:
        """Servers per rack."""
        return self.K // self.P

    @property
    def n_layers(self) -> int:
        """Number of server layers (= Kr)."""
        return self.Kr

    @property
    def subfiles_per_layer(self) -> int:
        """N P / K subfiles per layer in the hybrid scheme."""
        return self.N * self.P // self.K

    @property
    def M(self) -> int:
        """Subfiles per (layer, rack r-subset) slot: (NP/K) / C(P, r)."""
        return self.subfiles_per_layer // comb(self.P, self.r)

    @property
    def J(self) -> int:
        """Coded MapReduce: subfiles per server r-subset, N / C(K, r)."""
        return self.N // comb(self.K, self.r)

    # ---- resolvable-family derived quantities ------------------------------

    @property
    def spc_q(self) -> int:
        """Racks per parallel class of the resolvable family, q = P / r."""
        return self.P // self.r

    @property
    def spc_batches(self) -> int:
        """Subfile batches per layer of the resolvable family: the q^{r-1}
        codewords of the (r, r-1) single-parity-check code over Z_q."""
        return self.spc_q ** (self.r - 1)

    @property
    def M_res(self) -> int:
        """Resolvable family: subfiles per (layer, batch), (NP/K)/q^{r-1}."""
        return self.subfiles_per_layer // self.spc_batches

    # ---- per-scheme divisibility checks ------------------------------------

    def validate_uncoded(self) -> None:
        _check(self.N % self.K == 0, f"uncoded needs K|N; K={self.K} N={self.N}")
        _check(self.Q % self.K == 0, f"uncoded needs K|Q; K={self.K} Q={self.Q}")

    def validate_coded(self) -> None:
        c = comb(self.K, self.r)
        _check(self.N % c == 0,
               f"coded needs C(K,r)|N; C({self.K},{self.r})={c} N={self.N}")
        _check(self.Q % self.K == 0, f"coded needs K|Q; K={self.K} Q={self.Q}")
        _check(self.r < self.K, "coded needs r < K")

    def validate_hybrid(self) -> None:
        _check(self.r <= self.P, f"hybrid needs r <= P; r={self.r} P={self.P}")
        _check(self.N * self.P % self.K == 0,
               f"hybrid needs K | N*P; K={self.K} N={self.N} P={self.P}")
        c = comb(self.P, self.r)
        _check(self.subfiles_per_layer % c == 0,
               f"hybrid needs C(P,r)|(NP/K); C({self.P},{self.r})={c} "
               f"NP/K={self.subfiles_per_layer}")
        _check(self.Q % self.K == 0, f"hybrid needs K|Q; K={self.K} Q={self.Q}")

    def validate_hybrid_resolvable(self) -> None:
        """Resolvable-design family (see repro.core.resolvable): needs r >= 2
        parallel classes of q = P/r >= 2 racks, q^{r-1} | NP/K subfile
        batches, and r-1 shares per missing batch."""
        _check(self.r >= 2,
               f"resolvable needs r >= 2; r={self.r}")
        _check(self.P % self.r == 0,
               f"resolvable needs r|P; r={self.r} P={self.P}")
        _check(self.spc_q >= 2,
               f"resolvable needs q=P/r >= 2; P={self.P} r={self.r}")
        _check(self.N * self.P % self.K == 0,
               f"resolvable needs K | N*P; K={self.K} N={self.N} P={self.P}")
        b = self.spc_batches
        _check(self.subfiles_per_layer % b == 0,
               f"resolvable needs q^(r-1)|(NP/K); q^(r-1)={b} "
               f"NP/K={self.subfiles_per_layer}")
        _check(self.M_res % (self.r - 1) == 0,
               f"resolvable needs (r-1)|M; M={self.M_res} r={self.r}")
        _check(self.Q % self.K == 0,
               f"resolvable needs K|Q; K={self.K} Q={self.Q}")

    # ---- topology helpers ---------------------------------------------------

    def rack_of(self, server: int) -> int:
        """Rack index of a flat server id."""
        return server // self.Kr

    def slot_of(self, server: int) -> int:
        """In-rack slot (== layer) of a flat server id."""
        return server % self.Kr

    def server_id(self, rack: int, slot: int) -> int:
        return rack * self.Kr + slot

    def keys_of_server(self, server: int) -> range:
        """The paper assigns Q/K contiguous keys to each server."""
        per = self.Q // self.K
        return range(server * per, (server + 1) * per)

    def server_of_key(self, key: int) -> int:
        return key // (self.Q // self.K)

    def rack_of_key(self, key: int) -> int:
        return self.rack_of(self.server_of_key(key))

    def keys_of_rack(self, rack: int) -> range:
        per = self.Q // self.P
        return range(rack * per, (rack + 1) * per)


# The paper's Table I grid: (K, P, Q, N, r) of its nine experiment rows.
# Single source of truth for every bench/experiment that sweeps the grid
# (benchmarks/table1_costs.py, benchmarks/sim_bench.py,
# repro.resilience.experiments); three rows violate C(P,r) | NP/K and are
# evaluated with check=False, exactly as the paper implicitly did.
TABLE1_GRID = (
    (9, 3, 18, 72, 2),
    (16, 4, 16, 240, 2),
    (16, 4, 16, 1680, 3),
    (15, 3, 15, 210, 2),
    (20, 4, 20, 380, 2),
    (25, 5, 25, 600, 2),
    (25, 5, 25, 6900, 3),
    (30, 5, 30, 870, 2),
    (30, 6, 30, 870, 2),
)
