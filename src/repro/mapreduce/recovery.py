"""Engine recovery ladder: run a distributed job to completion under
injected server crashes.

Three rungs, cheapest first (the r-fold map replication is an erasure code
— see :mod:`repro.core.degraded`):

1. **decode-around** — every row lost with the crashed servers still has a
   surviving replica owner (guaranteed for any f <= r-1 failures per
   multicast group), so a degraded plan re-routes stage 1 around the dead
   servers and NOTHING is re-mapped;
2. **partial re-map** — subfiles that lost ALL r owners (orphans) are
   re-mapped on survivors and injected into stage 1 as an additive table
   patch; everything else still decodes around;
3. **bounded-retry restart** — unrecoverable attempts (every server dead,
   or orphans with ``allow_partial_remap=False``) burn one restart from the
   shared :class:`repro.resilience.backoff.RestartBudget` (jittered
   exponential backoff — the same accountant as the trainer's
   checkpoint/resume loop) and re-enter the ladder on the injector's next
   attempt schedule.

Every rung produces outputs BIT-IDENTICAL to the failure-free run: degraded
stage-1 tables reconstruct exactly the failure-free tables (repair reads
are raw replica rows; orphan patches are exact re-mapped values), and
map/stage-2/reduce run the same per-device programs as the fused pipeline.
The 8-device driver and ``benchmarks/faults_bench.py`` assert this for both
plan families.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.degraded import DegradedPlan, build_patch, compile_degraded_plan
from ..core.coded_collectives import device_plan_tables, shuffle_device_body
from ..core.params import SchemeParams
from ..distributed.meshes import shard_map
from ..obs import metrics as obs_metrics
from ..obs.bytes import degraded_rack_bytes, record_rack_bytes
from ..resilience.backoff import RestartBudget
from ..resilience.faults import FaultSpec

RECOVERY_RUNGS = ("none", "decode_around", "partial_remap", "restart")


class UnrecoverableFailure(RuntimeError):
    """An attempt cannot be salvaged by degraded execution (every server
    dead, or orphaned subfiles with partial re-map disabled) — escalates to
    the restart rung."""


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """How a faulted job actually finished: which ladder rung produced the
    returned outputs, which servers were dead during the successful
    attempt, how many subfiles were re-mapped, and the restart accounting
    (delays are the recorded backoff schedule, slept only if the
    :class:`FaultSpec` carried a sleeper)."""
    rung: str
    failed: Tuple[int, ...]
    n_remapped: int
    restarts: int
    backoff_delays: Tuple[float, ...]
    attempts: int


@functools.lru_cache(maxsize=32)
def _degraded_executable(job, dplan: DegradedPlan, mesh: Mesh,
                         combine_impl: str):
    """One jitted shard_map program for a degraded attempt: per-device map
    -> crash mask -> degraded unicast shuffle (+ orphan patch) -> reduce.

    Identical per-device structure to the failure-free fused pipeline (same
    vmap'd map, same device body, same reduce), so surviving devices
    compute bit-identical rows.  The crash mask zeroes the failed devices'
    map outputs INSIDE the program — the replacement worker at that mesh
    coordinate participates in the collective with empty memory, and tests
    poison those values to prove nothing flows out of dead state.  Cached
    per (job, degraded-plan, mesh) like the fused executable.
    """
    p = dplan.params
    plan = dplan.plan
    tables = device_plan_tables(plan)
    alive = np.ones((p.P, p.Kr), dtype=bool)
    for s in dplan.failed:
        alive[s // p.Kr, s % p.Kr] = False
    alive_t = jnp.asarray(alive)

    def device_fn(subs, patch):      # [1, n_loc, ...], [1, n_layer, qr, d]
        i = jax.lax.axis_index("rack")
        j = jax.lax.axis_index("server")
        vals = jax.vmap(lambda s: job.map_fn(s, p.Q))(subs[0])  # [n_loc,Q,d]
        vals = jnp.where(alive_t[i, j], vals, jnp.zeros_like(vals))
        rows = shuffle_device_body(vals, plan, tables, "unicast",
                                   combine_impl,
                                   patch=patch[0].astype(vals.dtype))
        return jax.vmap(job.reduce_fn, in_axes=1)(rows)[None]   # [1,q_srv,*]

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(("rack", "server")), P(("rack", "server"))),
                   out_specs=P(("rack", "server")),
                   check=combine_impl != "pallas")
    return jax.jit(fn)


def _degraded_attempt(job, subfiles: np.ndarray, p: SchemeParams, mesh: Mesh,
                      failed: Tuple[int, ...], faults: FaultSpec, *,
                      combine_impl: str, placement, scheme_family: str):
    """Rungs 1-2: degraded execution around ``failed``; returns
    (outputs [K, q_srv, d_out], degraded plan, n_remapped, rung)."""
    from .engine import pack_local_subfiles
    if len(failed) >= p.K:
        raise UnrecoverableFailure(
            f"all {p.K} servers failed; no survivors to recover on")
    perm = getattr(placement, "perm", placement)
    dplan = compile_degraded_plan(p, failed, family=scheme_family, perm=perm)
    n_remap = int(dplan.orphan_subfiles.size)
    if n_remap and not faults.allow_partial_remap:
        raise UnrecoverableFailure(
            f"{n_remap} subfiles lost all {p.r} owners and partial re-map "
            f"is disabled")
    local_subs = jnp.asarray(pack_local_subfiles(subfiles, dplan.base))
    q_rack = p.Q // p.P
    if n_remap:
        # rung 2: re-map ONLY the orphaned subfiles on survivors (the
        # re-map work the sim prices) and inject them as a stage-1 patch
        remap = jax.jit(jax.vmap(lambda s: job.map_fn(s, p.Q)))
        orphan_vals = np.asarray(
            remap(jnp.asarray(np.asarray(subfiles)[dplan.orphan_subfiles])))
        patch = build_patch(dplan, orphan_vals)
    else:
        patch = np.zeros((p.K, p.subfiles_per_layer, q_rack, job.d),
                         dtype=np.float32)
    exe = _degraded_executable(job, dplan, mesh, combine_impl)
    out = exe(local_subs, jnp.asarray(patch))
    rung = "partial_remap" if n_remap else "decode_around"
    return out, dplan, n_remap, rung


def run_with_recovery(job, subfiles: np.ndarray, p: SchemeParams, mesh: Mesh,
                      faults: FaultSpec, *, multicast: str = "unicast",
                      combine_impl: str = "xla", placement=None,
                      scheme_family: str = "binomial"):
    """Execute ``job`` under the fault schedule, climbing the recovery
    ladder until an attempt completes; returns the
    :class:`repro.mapreduce.engine.JobResult` with ``.recovery`` filled.

    ``p`` must already carry the effective r (the engine resolves the
    override before dispatching here).  Attempt k applies
    ``faults.injector.events_for_attempt(k)``; an attempt with no scheduled
    events runs the plain failure-free path (that is how transient failures
    resolve after a restart).
    """
    from .engine import JobResult, assemble_outputs, run_job_distributed
    budget = RestartBudget(max_restarts=faults.max_restarts,
                           policy=faults.backoff, seed=faults.seed,
                           sleep=faults.sleep)
    attempt = 0
    while True:
        events = faults.injector.events_for_attempt(attempt)
        failed = tuple(sorted({s for e in events for s in e.servers}))
        try:
            if not failed:
                res = run_job_distributed(
                    job, subfiles, p, mesh, fused=True, multicast=multicast,
                    combine_impl=combine_impl, placement=placement,
                    scheme_family=scheme_family)
                rung = "none" if attempt == 0 else "restart"
                _record_rung(rung, scheme_family)
                res.recovery = RecoveryReport(
                    rung, failed, 0, budget.restarts, tuple(budget.delays),
                    attempt + 1)
                return res
            out, dplan, n_remap, rung = _degraded_attempt(
                job, subfiles, p, mesh, failed, faults,
                combine_impl=combine_impl, placement=placement,
                scheme_family=scheme_family)
            final = assemble_outputs(out, dplan.plan)
            from ..core.costs import hybrid_cost, hybrid_resolvable_cost
            from ..core.plan_registry import scheme_of_family
            c = (hybrid_resolvable_cost(p) if scheme_family == "resolvable"
                 else hybrid_cost(p))
            scheme = scheme_of_family(scheme_family)
            # the degraded attempt's ACTUAL wire bytes (unicast repair
            # schedule + orphan redistribution), not the failure-free
            # closed form — what a recovery really moved
            rb = record_rack_bytes(degraded_rack_bytes(dplan, job.d),
                                   scheme, scheme_family,
                                   layer="engine_degraded")
            _record_rung(rung, scheme_family)
            res = JobResult(final, c.intra, c.cross, scheme,
                            intra_rack_bytes=rb.intra_total,
                            cross_rack_bytes=rb.cross_total)
            res.recovery = RecoveryReport(
                rung, failed, n_remap, budget.restarts,
                tuple(budget.delays), attempt + 1)
            return res
        except UnrecoverableFailure as e:
            budget.next_restart(e)    # raises e when the budget is spent
            obs_metrics.counter(
                "engine_restarts_total",
                "restart-budget consumption of the recovery ladder").inc(
                    family=scheme_family)
            attempt += 1


def _record_rung(rung: str, family: str) -> None:
    obs_metrics.counter(
        "recovery_rung_total",
        "recovery-ladder rung that produced the returned outputs").inc(
            rung=rung, family=family)


__all__ = ["RecoveryReport", "RECOVERY_RUNGS", "UnrecoverableFailure",
           "run_with_recovery"]
