from .engine import MapReduceJob, run_job, run_job_distributed  # noqa: F401
from .jobs import histogram_job, groupby_mean_job, terasort_bucket_job  # noqa: F401
