"""Executable MapReduce engine over jnp arrays.

A job maps each subfile to a dense intermediate tensor V_i in R^{Q x d}
(one length-d value per reduce key), shuffles so the reducer of key q holds
{V_i[q] : all i}, and reduces per key.  The engine runs under any of the
paper's three shuffle schemes and reports the paper-metric communication
costs alongside the (bit-exact) results.

Two execution paths:
  * run_job            — single-device: dense shuffle oracle + analytic costs
  * run_job_distributed — multi-device: the real two-stage shard_map shuffle
    of :mod:`repro.core.coded_collectives` over a ('rack','server') mesh.
    Default ``fused=True`` runs map -> pack -> shuffle -> reduce as ONE
    jitted, device-resident shard_map program: each device maps only its own
    n_loc assigned subfiles, packs via on-device gathers from the plan's
    cached index-table constants, shuffles, and reduces its own keys — zero
    host transfers between phases, input buffer donated.  ``fused=False``
    keeps the legacy host-round-trip path (single-device map of all N, host
    NumPy packing, re-upload) for comparison — see
    ``benchmarks/pipeline_bench.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.assignment import (coded_assignment, hybrid_assignment,
                               uncoded_assignment)
from ..core.coded_collectives import (HybridShufflePlan,
                                      compile_hybrid_plan,
                                      device_plan_tables,
                                      hybrid_shuffle, pack_local_values,
                                      reduce_output_keys,
                                      reduce_ready_order,
                                      shuffle_device_body)
from ..core.costs import (coded_cost, hybrid_cost, hybrid_resolvable_cost,
                          uncoded_cost)
from ..core.params import SchemeParams
from ..core.plan_registry import scheme_of_family
from ..core.resolvable import resolvable_assignment
from ..core.shuffle_plan import count_plan, make_plan
from ..distributed.meshes import shard_map
from ..obs.bytes import plan_rack_bytes, reconcile, record_rack_bytes
from ..obs.metrics import refresh_cache_metrics
from ..obs.tracing import get_tracer, spans_from_phase_timings


@dataclasses.dataclass(frozen=True)
class MapReduceJob:
    name: str
    d: int                                    # payload width per (key, subfile)
    map_fn: Callable[[jax.Array, int], jax.Array]   # subfile data -> [Q, d]
    reduce_fn: Callable[[jax.Array], jax.Array]     # [N, d] -> [d_out]


@dataclasses.dataclass
class JobResult:
    outputs: jax.Array                        # [Q, d_out] final reduced values
    intra_cost: float                         # paper metric (kv pairs)
    cross_cost: float
    scheme: str
    # filled by the recovery ladder when the job ran under injected faults
    # (repro.mapreduce.recovery.RecoveryReport); None on failure-free runs
    recovery: object | None = None
    # rack-level byte accounting in value-units (pairs x payload width d),
    # paper-metric counting, derived from the ACTUAL compiled plan and
    # reconciled against the closed forms (repro.obs.bytes) — the same
    # fields JobStats carries on the sim side
    intra_rack_bytes: float = 0.0
    cross_rack_bytes: float = 0.0
    # measured-wall-clock blame components (repro.obs.blame schema) from
    # the run's engine_phase trace spans; None when tracing is disabled.
    # Components sum to the total traced phase wall (the engine-side
    # exactness law) — the fused device program stays one indivisible
    # 'map_shuffle_reduce' entry rather than a fabricated per-phase split
    blame: Dict[str, float] | None = None


def _validate_mesh(mesh: Mesh, p: SchemeParams) -> None:
    """Fail fast (and legibly) on a mesh that does not realize the scheme's
    (P racks) x (Kr servers) grid — a mismatch otherwise surfaces deep
    inside shard_map as an opaque XLA shape error."""
    names = tuple(mesh.axis_names)
    if "rack" not in names or "server" not in names:
        raise ValueError(
            f"mesh must have axes ('rack', 'server'); got {names!r}")
    shape = dict(mesh.shape)
    if shape["rack"] != p.P or shape["server"] != p.Kr:
        raise ValueError(
            f"mesh shape (rack={shape['rack']}, server={shape['server']}) "
            f"does not match SchemeParams: need rack=P={p.P}, "
            f"server=Kr={p.Kr} (K={p.K} servers in {p.P} racks)")


def _assignment_for(params: SchemeParams, scheme: str):
    return {"uncoded": uncoded_assignment,
            "coded": coded_assignment,
            "hybrid": hybrid_assignment,
            "hybrid_resolvable": resolvable_assignment}[scheme](params)


def map_phase(job: MapReduceJob, subfiles: jax.Array, Q: int) -> jax.Array:
    """[N, ...] subfile data -> V[N, Q, d]."""
    return jax.vmap(lambda s: job.map_fn(s, Q))(subfiles)


def run_job(job: MapReduceJob, subfiles: jax.Array, params: SchemeParams,
            scheme: str = "hybrid", count_messages: bool = False) -> JobResult:
    """Single-device execution with the paper's communication accounting.

    ``count_messages=True`` counts the explicit schedule (slow, exact);
    otherwise the closed forms of Props 1-2 / Thm III.1 are used — the two
    are proven equal in tests.
    """
    V = map_phase(job, subfiles, params.Q)              # [N, Q, d]
    outputs = jax.vmap(job.reduce_fn, in_axes=1)(V)     # [Q, d_out]
    if count_messages:
        a = _assignment_for(params, scheme)
        counts = count_plan(make_plan(a), params)
        intra, cross = float(counts.intra), float(counts.cross)
    else:
        cost_fn = {"uncoded": uncoded_cost, "coded": coded_cost,
                   "hybrid": hybrid_cost,
                   "hybrid_resolvable": hybrid_resolvable_cost}[scheme]
        c = cost_fn(params)
        intra, cross = c.intra, c.cross
    return JobResult(outputs, intra, cross, scheme,
                     intra_rack_bytes=intra * job.d,
                     cross_rack_bytes=cross * job.d)


def pack_local_subfiles(subfiles: np.ndarray,
                        plan: HybridShufflePlan) -> np.ndarray:
    """Distribute raw subfile data into the fused pipeline's per-device
    layout: [K, n_loc, ...] — device (i, j)'s rows are ITS assigned subfiles
    in ``plan.local_subfiles[i, j]`` order (the only host-side step of the
    fused path; everything after lives on device)."""
    p = plan.params
    return np.asarray(subfiles)[plan.local_subfiles.reshape(p.K, -1)]


def assemble_outputs(out: jax.Array, plan: HybridShufflePlan) -> jax.Array:
    """[K, Q/K, d_out] per-server reduce rows -> [Q, d_out] in global key
    order, derived explicitly from :func:`reduce_output_keys` (row m of the
    flattened output holds key ``keys.ravel()[m]``, which is m only for the
    default contiguous partition)."""
    keys = reduce_output_keys(plan)
    flat = out.reshape(out.shape[0] * out.shape[1], -1)
    return flat[np.argsort(keys.reshape(-1), kind="stable")]


@functools.lru_cache(maxsize=64)
def _fused_executable(job: MapReduceJob, plan: HybridShufflePlan, mesh: Mesh,
                      multicast: str, combine_impl: str):
    """Compile the end-to-end device-resident pipeline for (job, plan, mesh):
    ONE jitted shard_map program running map, pack, two-stage shuffle and
    reduce with no host round-trip.

    The cache keys on the job OBJECT (its map/reduce closures compare by
    identity, standard jit semantics) — reuse one job instance across calls
    to hit the compiled executable; a fresh factory call recompiles.  The
    packed input is donated so XLA may reuse its buffer where shapes/dtypes
    admit aliasing; intermediates of the fused program are XLA-managed and
    never materialize host-side at all."""
    p = plan.params
    tables = device_plan_tables(plan)       # on-device constants, plan-cached

    def device_fn(subs):                    # [1, n_loc, ...subfile dims]
        vals = jax.vmap(lambda s: job.map_fn(s, p.Q))(subs[0])  # [n_loc,Q,d]
        rows = shuffle_device_body(vals, plan, tables, multicast,
                                   combine_impl)                # [N,q_srv,d]
        return jax.vmap(job.reduce_fn, in_axes=1)(rows)[None]   # [1,q_srv,*]

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(("rack", "server")),),
                   out_specs=P(("rack", "server")),
                   check=combine_impl != "pallas")
    # donate the packed input: XLA aliases it into the program where
    # shapes/dtypes admit (a no-op otherwise); donation is unimplemented on
    # the cpu backend (warns and copies), so gate it
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(fn, donate_argnums=donate)


def _blame_from_spans(events, cost) -> Dict[str, float] | None:
    """Fold one run's ``engine_phase`` trace spans into blame components
    (:mod:`repro.obs.blame` schema).  Host phases map directly; a measured
    legacy ``shuffle`` wall is split ``shuffle_cross`` / ``shuffle_intra``
    by the scheme's closed-form unit ratio (the same convention as
    :func:`repro.obs.blame.blame_from_phase_timings`); the fused device
    program is kept whole under ``map_shuffle_reduce``.  Returns None when
    no spans were traced (tracing disabled)."""
    phases: Dict[str, float] = {}
    for ev in events:
        if ev.kind == "engine_phase" and ev.dur is not None:
            phases[ev.phase] = phases.get(ev.phase, 0.0) + float(ev.dur)
    if not phases:
        return None
    comps: Dict[str, float] = {}
    for k in ("plan_compile", "map", "pack", "reduce",
              "map_shuffle_reduce"):
        if k in phases:
            comps[k] = phases[k]
    if "shuffle" in phases:
        tot = cost.intra + cost.cross
        frac = cost.cross / tot if tot > 0 else 0.5
        comps["shuffle_cross"] = phases["shuffle"] * frac
        comps["shuffle_intra"] = phases["shuffle"] * (1.0 - frac)
    return comps


def run_job_distributed(job: MapReduceJob, subfiles: np.ndarray,
                        params: SchemeParams, mesh: Mesh,
                        r: int | None = None, *, fused: bool = True,
                        multicast: str = "unicast",
                        combine_impl: str = "xla",
                        placement: object | None = None,
                        scheme_family: str = "binomial",
                        faults: object | None = None) -> JobResult:
    """Multi-device execution: real all_to_all shuffle (hybrid scheme,
    general map-replication r in [1, P]).

    ``scheme_family`` selects the registered plan compiler: ``'binomial'``
    (the paper's construction) or ``'resolvable'`` (the SPC design of
    :mod:`repro.core.resolvable`, feasible at K far beyond the binomial
    divisibility wall — see docs/scaling.md).  Every downstream stage is
    family-agnostic: the fused executable caches on the plan object, and
    costs come from the family's closed form.

    ``mesh`` must have axes ('rack', 'server') with sizes (P, Kr).  Each
    device maps only ITS assigned subfiles (with r-fold replication across
    racks), shuffles via the two-stage hybrid schedule, and reduces its own
    keys.  ``r`` overrides ``params.r`` (the knob for sweeping the paper's
    computation/communication tradeoff curve).  Returns outputs identical
    to :func:`run_job` (asserted in tests).

    ``fused=True`` (default) runs the whole map->pack->shuffle->reduce chain
    as one jitted device-resident program (zero inter-phase host transfers);
    ``fused=False`` is the legacy path: dense single-device map of ALL N
    subfiles, host-side packing, re-upload, then the shuffle.  ``multicast``
    and ``combine_impl`` are forwarded to the shuffle (coded multicast
    packets and the Pallas f(.) kernels — see
    :func:`repro.core.coded_collectives.shuffle_device_body`).

    ``placement`` runs the job under a Section-IV locality-optimized layout:
    a :class:`repro.placement.PlacementResult` (or a bare slot permutation)
    whose perm decides which subfile each device maps — the shuffle index
    tables are permutation-invariant, so outputs are unchanged while each
    device's map inputs become the placement's (the real-cluster analogue of
    the simulator's fetch-traffic bridge).

    ``faults`` (a :class:`repro.resilience.faults.FaultSpec`) runs the job
    under injected server crashes through the recovery ladder of
    :mod:`repro.mapreduce.recovery` — decode-around, partial re-map, then
    bounded-retry restart — and fills ``JobResult.recovery``; outputs stay
    bit-identical to the failure-free run.
    """
    p = params if r is None or r == params.r else \
        dataclasses.replace(params, r=r)
    _validate_mesh(mesh, p)
    if faults is not None:
        from .recovery import run_with_recovery
        res = run_with_recovery(job, subfiles, p, mesh, faults,
                                multicast=multicast,
                                combine_impl=combine_impl,
                                placement=placement,
                                scheme_family=scheme_family)
        refresh_cache_metrics()
        return res
    perm = getattr(placement, "perm", placement)
    tracer = get_tracer()
    span_lo = len(tracer.events)
    with tracer.span("plan_compile", kind="engine_phase",
                     job=job.name, family=scheme_family):
        plan = compile_hybrid_plan(p, perm=perm, family=scheme_family)
    if fused:
        with tracer.span("pack", kind="engine_phase", job=job.name):
            local_subs = jnp.asarray(pack_local_subfiles(subfiles, plan))
        with tracer.span("map_shuffle_reduce", kind="engine_phase",
                         job=job.name, fused="true"):
            exe = _fused_executable(job, plan, mesh, multicast, combine_impl)
            out = exe(local_subs)                       # [K, q_srv, d_out]
            jax.block_until_ready(out)
    else:
        with tracer.span("map", kind="engine_phase", job=job.name):
            V = np.asarray(map_phase(job, jnp.asarray(subfiles), p.Q))
        with tracer.span("pack", kind="engine_phase", job=job.name):
            local = pack_local_values(V, plan)          # [K, n_loc, Q, d]
        with tracer.span("shuffle", kind="engine_phase", job=job.name):
            shuffled = hybrid_shuffle(jnp.asarray(local), plan, mesh,
                                      multicast, combine_impl)
            jax.block_until_ready(shuffled)
        with tracer.span("reduce", kind="engine_phase", job=job.name):
            # [K, N, q_srv, d]; rows ordered by reduce_ready_order
            out = jax.vmap(jax.vmap(job.reduce_fn, in_axes=1))(shuffled)
            jax.block_until_ready(out)
    final = assemble_outputs(out, plan)                 # [Q, d_out]
    scheme = scheme_of_family(scheme_family)
    c = (hybrid_resolvable_cost(p) if scheme_family == "resolvable"
         else hybrid_cost(p))
    # rack-level byte accounting off the ACTUAL compiled plan, paper-metric
    # counting, re-reconciled against the closed form on every run
    rb = record_rack_bytes(plan_rack_bytes(plan, "coded", job.d),
                           scheme, scheme_family, layer="engine")
    reconcile(rb.intra_total, rb.cross_total, p, scheme, d=job.d,
              check=False)
    # cache gauges stay current in snapshots without a manual pull
    refresh_cache_metrics()
    return JobResult(final, c.intra, c.cross, scheme,
                     intra_rack_bytes=rb.intra_total,
                     cross_rack_bytes=rb.cross_total,
                     blame=_blame_from_spans(tracer.events[span_lo:], c))


# ---------------------------------------------------------------------------
# Per-phase timing instrumentation (calibration feed for repro.sim)
# ---------------------------------------------------------------------------

def _best_of(fn: Callable[[], object], iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def measure_phase_timings(job: MapReduceJob, subfiles: np.ndarray,
                          params: SchemeParams, mesh: Mesh,
                          iters: int = 3) -> Dict[str, object]:
    """Measure REAL per-phase wall clock of the hybrid pipeline, in the row
    format :func:`repro.sim.cluster.calibrate` consumes.

    Phases are timed separately on warm jitted executables: plan compile
    (cold, LRU cache cleared), map (all N subfiles), host pack, distributed
    shuffle, and reduce.  ``work`` holds the value-unit conventions of
    :class:`repro.sim.cluster.CostModel`; the fitted beta is therefore a
    per-value-unit rate of THIS host — a calibration proxy, not a TPU claim
    (the simulator divides work across the K simulated servers).
    """
    from ..core.coded_collectives import plan_cache_clear

    p = params
    plan_cache_clear()
    t0 = time.perf_counter()
    plan = compile_hybrid_plan(p)
    compile_s = time.perf_counter() - t0

    subs_dev = jnp.asarray(subfiles)
    map_jit = jax.jit(lambda s: map_phase(job, s, p.Q))
    V_host = np.asarray(map_jit(subs_dev))                       # warm-up
    map_s = _best_of(lambda: np.asarray(map_jit(subs_dev)), iters)

    pack_s = _best_of(
        lambda: jnp.asarray(pack_local_values(V_host, plan)
                            ).block_until_ready(), iters)
    local_dev = jnp.asarray(pack_local_values(V_host, plan))

    shuf_jit = jax.jit(lambda v: hybrid_shuffle(v, plan, mesh))
    shuffled = shuf_jit(local_dev)
    shuffled.block_until_ready()                                 # warm-up
    shuffle_s = _best_of(
        lambda: shuf_jit(local_dev).block_until_ready(), iters)

    red_jit = jax.jit(jax.vmap(jax.vmap(job.reduce_fn, in_axes=1)))
    red_jit(shuffled).block_until_ready()                        # warm-up
    reduce_s = _best_of(
        lambda: red_jit(shuffled).block_until_ready(), iters)

    d = job.d
    row = {
        "work": {
            "map": float(p.N) * p.Q * d,
            "pack": float(p.K) * plan.local_subfiles.shape[-1] * p.Q * d,
            "reduce": float(p.N) * p.Q * d,
            "plan_compile": float(p.N),
        },
        "seconds": {"map": map_s, "pack": pack_s, "reduce": reduce_s,
                    "plan_compile": compile_s},
        "meta": {"K": p.K, "P": p.P, "Q": p.Q, "N": p.N, "r": p.r, "d": d,
                 "job": job.name, "shuffle_s": shuffle_s,
                 "backend": jax.default_backend()},
    }
    if get_tracer().enabled:        # device-timing spans for trace export
        spans_from_phase_timings(row)
    return row


def measure_calibration_grid(job_factory: Callable[[int], MapReduceJob],
                             mesh: Mesh, points: List[tuple],
                             iters: int = 3) -> List[Dict[str, object]]:
    """Run :func:`measure_phase_timings` over (params, d) points — enough
    rows for the affine per-phase fit of :func:`repro.sim.cluster.calibrate`
    to be overdetermined."""
    rows = []
    for params, d in points:
        job = job_factory(d)
        rng = np.random.default_rng(params.N)
        subs = rng.integers(0, 1 << 16,
                            size=(params.N, 256)).astype(np.int32)
        rows.append(measure_phase_timings(job, subs, params, mesh, iters))
    return rows
