"""Executable MapReduce engine over jnp arrays.

A job maps each subfile to a dense intermediate tensor V_i in R^{Q x d}
(one length-d value per reduce key), shuffles so the reducer of key q holds
{V_i[q] : all i}, and reduces per key.  The engine runs under any of the
paper's three shuffle schemes and reports the paper-metric communication
costs alongside the (bit-exact) results.

Two execution paths:
  * run_job            — single-device: dense shuffle oracle + analytic costs
  * run_job_distributed — multi-device: the real two-stage shard_map shuffle
    of :mod:`repro.core.coded_collectives` over a ('rack','server') mesh
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core.assignment import (coded_assignment, hybrid_assignment,
                               uncoded_assignment)
from ..core.coded_collectives import (HybridShufflePlan,
                                      compile_hybrid_plan,
                                      hybrid_shuffle, pack_local_values,
                                      reduce_ready_order)
from ..core.costs import coded_cost, hybrid_cost, uncoded_cost
from ..core.params import SchemeParams
from ..core.shuffle_plan import count_plan, make_plan


@dataclasses.dataclass(frozen=True)
class MapReduceJob:
    name: str
    d: int                                    # payload width per (key, subfile)
    map_fn: Callable[[jax.Array, int], jax.Array]   # subfile data -> [Q, d]
    reduce_fn: Callable[[jax.Array], jax.Array]     # [N, d] -> [d_out]


@dataclasses.dataclass
class JobResult:
    outputs: jax.Array                        # [Q, d_out] final reduced values
    intra_cost: float                         # paper metric (kv pairs)
    cross_cost: float
    scheme: str


def _assignment_for(params: SchemeParams, scheme: str):
    return {"uncoded": uncoded_assignment,
            "coded": coded_assignment,
            "hybrid": hybrid_assignment}[scheme](params)


def map_phase(job: MapReduceJob, subfiles: jax.Array, Q: int) -> jax.Array:
    """[N, ...] subfile data -> V[N, Q, d]."""
    return jax.vmap(lambda s: job.map_fn(s, Q))(subfiles)


def run_job(job: MapReduceJob, subfiles: jax.Array, params: SchemeParams,
            scheme: str = "hybrid", count_messages: bool = False) -> JobResult:
    """Single-device execution with the paper's communication accounting.

    ``count_messages=True`` counts the explicit schedule (slow, exact);
    otherwise the closed forms of Props 1-2 / Thm III.1 are used — the two
    are proven equal in tests.
    """
    V = map_phase(job, subfiles, params.Q)              # [N, Q, d]
    outputs = jax.vmap(job.reduce_fn, in_axes=1)(V)     # [Q, d_out]
    if count_messages:
        a = _assignment_for(params, scheme)
        counts = count_plan(make_plan(a), params)
        intra, cross = float(counts.intra), float(counts.cross)
    else:
        cost_fn = {"uncoded": uncoded_cost, "coded": coded_cost,
                   "hybrid": hybrid_cost}[scheme]
        c = cost_fn(params)
        intra, cross = c.intra, c.cross
    return JobResult(outputs, intra, cross, scheme)


def run_job_distributed(job: MapReduceJob, subfiles: np.ndarray,
                        params: SchemeParams, mesh: Mesh,
                        r: int | None = None) -> JobResult:
    """Multi-device execution: real all_to_all shuffle (hybrid scheme,
    general map-replication r in [1, P]).

    ``mesh`` must have axes ('rack', 'server') with sizes (P, Kr).  Each
    device maps only ITS assigned subfiles (with r-fold replication across
    racks), shuffles via :func:`hybrid_shuffle`, and reduces its own keys.
    ``r`` overrides ``params.r`` (the knob for sweeping the paper's
    computation/communication tradeoff curve).  Returns outputs identical
    to :func:`run_job` (asserted in tests).
    """
    p = params if r is None or r == params.r else \
        dataclasses.replace(params, r=r)
    plan = compile_hybrid_plan(p)
    V = np.asarray(map_phase(job, jnp.asarray(subfiles), p.Q))   # [N, Q, d]
    local = pack_local_values(V, plan)                  # [K, n_loc, Q, d]

    shuffled = hybrid_shuffle(jnp.asarray(local), plan, mesh)
    # [K, N, q_srv, d]; per-device rows ordered by reduce_ready_order
    out = jax.vmap(jax.vmap(job.reduce_fn, in_axes=1))(shuffled)
    # out: [K, q_srv, d_out] -> assemble [Q, d_out] in key order
    final = out.reshape(p.Q, -1)
    c = hybrid_cost(p)
    return JobResult(final, c.intra, c.cross, "hybrid")
