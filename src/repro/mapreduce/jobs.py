"""Concrete MapReduce jobs used by the examples, tests and benchmarks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .engine import MapReduceJob


def histogram_job(vocab_hash_mod: int = 2**16) -> MapReduceJob:
    """WordCount-style: subfile = int32 token array; key = token bucket;
    value = occurrence count in the subfile.  Reduce = total count."""
    def map_fn(tokens: jax.Array, Q: int) -> jax.Array:
        bucket = (tokens.astype(jnp.uint32) % jnp.uint32(Q)).astype(jnp.int32)
        counts = jnp.zeros((Q,), jnp.int32).at[bucket].add(1)
        return counts[:, None].astype(jnp.float32)          # [Q, 1]

    def reduce_fn(vals: jax.Array) -> jax.Array:            # [N, 1]
        return vals.sum(axis=0)

    return MapReduceJob("histogram", 1, map_fn, reduce_fn)


def groupby_mean_job() -> MapReduceJob:
    """Group-by-key mean: subfile = [n, 2] (key_src, value) rows; emits
    per-bucket (sum, count); reduce = global mean per bucket."""
    def map_fn(rows: jax.Array, Q: int) -> jax.Array:
        keys = (rows[:, 0].astype(jnp.uint32) % jnp.uint32(Q)).astype(jnp.int32)
        vals = rows[:, 1].astype(jnp.float32)
        s = jnp.zeros((Q,), jnp.float32).at[keys].add(vals)
        c = jnp.zeros((Q,), jnp.float32).at[keys].add(1.0)
        return jnp.stack([s, c], axis=-1)                    # [Q, 2]

    def reduce_fn(vals: jax.Array) -> jax.Array:             # [N, 2]
        s, c = vals[:, 0].sum(), vals[:, 1].sum()
        return jnp.stack([s / jnp.maximum(c, 1.0), c])

    return MapReduceJob("groupby_mean", 2, map_fn, reduce_fn)


def wide_histogram_job(d: int) -> MapReduceJob:
    """Histogram with a width-d payload per (key, subfile): counts scaled by
    a fixed integer weight vector.  Integer-valued float32 throughout, so
    every execution path (including coded multicast encode/decode) is
    bit-exact — the shuffle-bound workload of ``benchmarks/pipeline_bench``.
    """
    def map_fn(tokens: jax.Array, Q: int) -> jax.Array:
        bucket = (tokens.astype(jnp.uint32) % jnp.uint32(Q)).astype(jnp.int32)
        counts = jnp.zeros((Q,), jnp.float32).at[bucket].add(1.0)
        w = (jnp.arange(d, dtype=jnp.float32) % 7.0) + 1.0
        return counts[:, None] * w[None, :]                  # [Q, d]

    def reduce_fn(vals: jax.Array) -> jax.Array:             # [N, d]
        return vals.sum(axis=0)

    return MapReduceJob(f"wide_histogram_d{d}", d, map_fn, reduce_fn)


def terasort_bucket_job(key_space: int = 2**20,
                        payload_quantiles: int = 8) -> MapReduceJob:
    """TeraSort bucketing phase (cf. CodedTeraSort [Li et al., 2017]): each
    reducer owns a contiguous key range; mappers emit, per range, the count
    and a fixed set of quantile summaries of their records landing in it.
    (The in-bucket sort is reducer-local compute, not shuffle traffic, so the
    shuffle cost model is exactly the paper's.)"""
    def map_fn(records: jax.Array, Q: int) -> jax.Array:
        rec = records.astype(jnp.float32)
        edges = jnp.linspace(0.0, float(key_space), Q + 1)
        bucket = jnp.clip(jnp.searchsorted(edges, rec, side="right") - 1,
                          0, Q - 1)
        counts = jnp.zeros((Q,), jnp.float32).at[bucket].add(1.0)
        sums = jnp.zeros((Q,), jnp.float32).at[bucket].add(rec)
        mins = jnp.full((Q,), jnp.inf).at[bucket].min(rec)
        maxs = jnp.full((Q,), -jnp.inf).at[bucket].max(rec)
        feats = [counts, sums, jnp.where(jnp.isfinite(mins), mins, 0.0),
                 jnp.where(jnp.isfinite(maxs), maxs, 0.0)]
        extra = payload_quantiles - len(feats)
        for k in range(max(extra, 0)):
            feats.append(counts * 0.0)
        return jnp.stack(feats[:payload_quantiles], axis=-1)  # [Q, pq]

    def reduce_fn(vals: jax.Array) -> jax.Array:              # [N, pq]
        counts = vals[:, 0].sum()
        sums = vals[:, 1].sum()
        mn = vals[:, 2].min()
        mx = vals[:, 3].max()
        return jnp.stack([counts, sums, mn, mx])

    return MapReduceJob("terasort_bucket", payload_quantiles, map_fn,
                        reduce_fn)
