"""Jit'd public wrapper for flash attention: model-layout adaptation
([B, S, H, hd] GQA), padding to block/lane boundaries, interpret fallback.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "q_offset", "kv_valid"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, kv_valid: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Model layout: q [B, Sq, H, hd]; k, v [B, Sk, KV, hd]; H = KV * G.
    Returns [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    ph = (-hd) % 128

    # [B, S, H, hd] -> [B*KV, G, Sq, hd] / [B*KV, Sk, hd]
    qg = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV, G, Sq, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    if pq or ph:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, pq), (0, ph)))
    if pk or ph:
        kg = jnp.pad(kg, ((0, 0), (0, pk), (0, ph)))
        vg = jnp.pad(vg, ((0, 0), (0, pk), (0, ph)))

    valid = Sk if kv_valid is None else kv_valid
    out = kernel.flash_attention_pallas(
        qg, kg, vg, causal=causal, window=window, q_offset=q_offset,
        kv_valid=valid, scale=hd ** -0.5,     # unpadded head-dim scale
        block_q=bq, block_k=bk, interpret=not _on_tpu())
    out = out[:, :, :Sq, :hd].reshape(B, KV, G, Sq, hd) \
        .transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out
