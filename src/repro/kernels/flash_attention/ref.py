"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        q_offset: int = 0,
                        kv_valid: Optional[int] = None) -> jax.Array:
    """q: [BH, G, Sq, hd]; k, v: [BH, Sk, hd] -> [BH, G, Sq, hd]."""
    BH, G, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bgqh,bkh->bgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = k_pos < (Sk if kv_valid is None else kv_valid)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgqk,bkh->bgqh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
