"""Pallas TPU flash attention (prefill hot spot).

Blockwise online-softmax attention with causal/window masking and GQA.
Grid: (batch*kv_heads, q_groups, q_blocks, kv_blocks); the kv axis is the
minormost (sequential on TPU) so the running (m, l, acc) state lives in
VMEM scratch across kv iterations and is finalized on the last kv block.

BlockSpec tiling (all VMEM):
  q:   (1, 1, block_q, hd)      fixed per (b, g, i), re-used over j
  k/v: (1, block_k, hd)         streamed over j
  out: (1, 1, block_q, hd)      written once at j == nk-1

MXU alignment: block_q/block_k default 128; hd is padded to 128 lanes by
ops.py.  Scores/accumulator are fp32; inputs may be bf16/fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window, block_q: int,
                  block_k: int, nk: int, q_offset: int, kv_valid: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                     # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = q_offset + i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_valid
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window=None,
                           q_offset: int = 0, kv_valid=None,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: [BH, G, Sq, hd]; k, v: [BH, Sk, hd] (BH = batch*kv_heads, G = GQA
    group).  Sq % block_q == 0, Sk % block_k == 0, hd % 128 == 0 (ops.py
    pads; ``scale`` carries the unpadded head dim's softmax scale).
    Returns [BH, G, Sq, hd] in q dtype."""
    BH, G, Sq, hd = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // block_q, Sk // block_k
    if kv_valid is None:
        kv_valid = Sk
    if scale is None:
        scale = hd ** -0.5 if hd else 1.0
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        window=window, block_q=block_q, block_k=block_k, nk=nk,
        q_offset=q_offset, kv_valid=kv_valid)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(BH, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, g, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, g, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, g, i, j: (b, g, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
