"""Pallas TPU kernels for the compute hot spots (validated in interpret
mode on CPU; see each subpackage's kernel.py for the BlockSpec tiling):

  coded_combine    — the paper's linear f(.) encode/decode (+ XOR variant)
  flash_attention  — blockwise online-softmax attention (prefill hot spot)
  rwkv_scan        — chunked WKV gated linear recurrence (long-context)
"""
