"""Pure-jnp oracle for the coded combine/decode kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def encode_ref(streams: jax.Array, coeffs: jax.Array) -> jax.Array:
    """streams: [r, T, d]; coeffs: [r] -> sum_i c_i v_i, in streams dtype."""
    acc = jnp.einsum("r,rtd->td", coeffs.astype(jnp.float32),
                     streams.astype(jnp.float32))
    return acc.astype(streams.dtype)


def decode_ref(f: jax.Array, known: jax.Array, coeffs: jax.Array,
               ) -> jax.Array:
    """coeffs[0] is the missing stream's coefficient; coeffs[1:] known."""
    acc = f.astype(jnp.float32) - jnp.einsum(
        "r,rtd->td", coeffs[1:].astype(jnp.float32),
        known.astype(jnp.float32))
    return (acc / coeffs[0].astype(jnp.float32)).astype(f.dtype)


def xor_encode_ref(streams: jax.Array) -> jax.Array:
    acc = streams[0]
    for i in range(1, streams.shape[0]):
        acc = acc ^ streams[i]
    return acc


def xor_decode_ref(f: jax.Array, known: jax.Array) -> jax.Array:
    acc = f
    for i in range(known.shape[0]):
        acc = acc ^ known[i]
    return acc
