"""Pallas TPU kernel for the paper's linear combining function f(.).

The Hybrid Coded MapReduce multicast payload is  f(v_1, ..., v_r) =
sum_i c_i * v_i  (eq. (1) of the paper); a receiver holding all but one
stream decodes the missing value as  (f - sum_known c_i v_i) / c_miss.
On TPU this encode/decode is a *memory-bound* fused multiply-accumulate
over the payload tensors — the hot inner loop of the shuffle engine, fused
here so each tile is read from HBM exactly once into VMEM.

Tiling: payloads are flattened to [T, d] tiles; the stream axis r is small
(the map replication factor, 2-4) and unrolled inside the kernel.  Block
shape (block_t, d) with d padded to the 128-lane boundary by ops.py.

An XOR (GF(2)) variant is provided for bit-exact integer shuffles
(CodedTeraSort-style): f = v_1 ^ ... ^ v_r, decode by re-XOR.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, c_ref, o_ref, *, r: int):
    """x: [r, bt, d]; c: [r] fp32; o: [bt, d] = sum_i c[i] * x[i]."""
    acc = c_ref[0] * x_ref[0].astype(jnp.float32)
    for i in range(1, r):
        acc += c_ref[i] * x_ref[i].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _decode_kernel(f_ref, x_ref, c_ref, o_ref, *, r: int):
    """f: [bt, d]; x (known): [r-1, bt, d]; c: [r] with c[0] = coefficient of
    the MISSING stream; c[1:] of the known ones.  o = (f - sum c_i x_i)/c[0].
    """
    acc = f_ref[...].astype(jnp.float32)
    for i in range(r - 1):
        acc -= c_ref[i + 1] * x_ref[i].astype(jnp.float32)
    o_ref[...] = (acc / c_ref[0]).astype(o_ref.dtype)


def _xor_encode_kernel(x_ref, o_ref, *, r: int):
    acc = x_ref[0]
    for i in range(1, r):
        acc = acc ^ x_ref[i]
    o_ref[...] = acc


def _xor_decode_kernel(f_ref, x_ref, o_ref, *, r: int):
    acc = f_ref[...]
    for i in range(r - 1):
        acc = acc ^ x_ref[i]
    o_ref[...] = acc


def encode_pallas(streams: jax.Array, coeffs: jax.Array, *,
                  block_t: int = 256, interpret: bool = True) -> jax.Array:
    """streams: [r, T, d]; coeffs: [r] fp32 -> f [T, d] (streams dtype)."""
    r, T, d = streams.shape
    nt = T // block_t
    return pl.pallas_call(
        functools.partial(_encode_kernel, r=r),
        out_shape=jax.ShapeDtypeStruct((T, d), streams.dtype),
        grid=(nt,),
        in_specs=[pl.BlockSpec((r, block_t, d), lambda i: (0, i, 0)),
                  pl.BlockSpec((r,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        interpret=interpret,
    )(streams, coeffs.astype(jnp.float32))


def decode_pallas(f: jax.Array, known: jax.Array, coeffs: jax.Array, *,
                  block_t: int = 256, interpret: bool = True) -> jax.Array:
    """f: [T, d]; known: [r-1, T, d]; coeffs: [r] (missing first)."""
    rm1, T, d = known.shape
    nt = T // block_t
    return pl.pallas_call(
        functools.partial(_decode_kernel, r=rm1 + 1),
        out_shape=jax.ShapeDtypeStruct((T, d), f.dtype),
        grid=(nt,),
        in_specs=[pl.BlockSpec((block_t, d), lambda i: (i, 0)),
                  pl.BlockSpec((rm1, block_t, d), lambda i: (0, i, 0)),
                  pl.BlockSpec((rm1 + 1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        interpret=interpret,
    )(f, known, coeffs.astype(jnp.float32))


def xor_encode_pallas(streams: jax.Array, *, block_t: int = 256,
                      interpret: bool = True) -> jax.Array:
    r, T, d = streams.shape
    nt = T // block_t
    return pl.pallas_call(
        functools.partial(_xor_encode_kernel, r=r),
        out_shape=jax.ShapeDtypeStruct((T, d), streams.dtype),
        grid=(nt,),
        in_specs=[pl.BlockSpec((r, block_t, d), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        interpret=interpret,
    )(streams)


def xor_decode_pallas(f: jax.Array, known: jax.Array, *, block_t: int = 256,
                      interpret: bool = True) -> jax.Array:
    rm1, T, d = known.shape
    nt = T // block_t
    return pl.pallas_call(
        functools.partial(_xor_decode_kernel, r=rm1 + 1),
        out_shape=jax.ShapeDtypeStruct((T, d), f.dtype),
        grid=(nt,),
        in_specs=[pl.BlockSpec((block_t, d), lambda i: (i, 0)),
                  pl.BlockSpec((rm1, block_t, d), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        interpret=interpret,
    )(f, known)
