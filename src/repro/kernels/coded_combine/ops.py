"""Jit'd public wrappers: shape normalization + padding for the coded
combine kernels.  Auto-selects interpret mode off-TPU."""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_t",))
def coded_encode(streams: Sequence[jax.Array], coeffs: jax.Array,
                 *, block_t: int = 256) -> jax.Array:
    """f(v_1..v_r) = sum_i c_i v_i.  streams: r arrays of equal shape."""
    xs = jnp.stack(streams)
    r = xs.shape[0]
    xs2 = xs.reshape(r, -1, xs.shape[-1])
    T, d = xs2.shape[1:]
    pd = (-d) % 128
    pt = (-T) % block_t
    xs2 = jnp.pad(xs2, ((0, 0), (0, pt), (0, pd)))
    out = kernel.encode_pallas(xs2, coeffs, block_t=block_t,
                               interpret=not _on_tpu())
    return out[:T, :d].reshape(streams[0].shape)


@partial(jax.jit, static_argnames=("block_t",))
def coded_decode(f: jax.Array, known: Sequence[jax.Array],
                 coeffs: jax.Array, *, block_t: int = 256) -> jax.Array:
    """Recover the missing stream; coeffs[0] = missing coefficient."""
    ks = jnp.stack(known)
    rm1 = ks.shape[0]
    shp = f.shape
    f2 = f.reshape(-1, shp[-1])
    ks2 = ks.reshape(rm1, -1, shp[-1])
    pd = (-shp[-1]) % 128
    pt = (-f2.shape[0]) % block_t
    T, d = f2.shape
    f2 = jnp.pad(f2, ((0, pt), (0, pd)))
    ks2 = jnp.pad(ks2, ((0, 0), (0, pt), (0, pd)))
    out = kernel.decode_pallas(f2, ks2, coeffs, block_t=block_t,
                               interpret=not _on_tpu())
    return out[:T, :d].reshape(shp)


@partial(jax.jit, static_argnames=("block_t",))
def xor_encode(streams: Sequence[jax.Array], *, block_t: int = 256,
               ) -> jax.Array:
    xs = jnp.stack(streams)
    r = xs.shape[0]
    shp = streams[0].shape
    xs2 = xs.reshape(r, -1, shp[-1])
    pd = (-shp[-1]) % 128
    pt = (-xs2.shape[1]) % block_t
    T, d = xs2.shape[1:]
    xs2 = jnp.pad(xs2, ((0, 0), (0, pt), (0, pd)))
    out = kernel.xor_encode_pallas(xs2, block_t=block_t,
                                   interpret=not _on_tpu())
    return out[:T, :d].reshape(shp)


@partial(jax.jit, static_argnames=("block_t",))
def xor_decode(f: jax.Array, known: Sequence[jax.Array],
               *, block_t: int = 256) -> jax.Array:
    ks = jnp.stack(known)
    rm1 = ks.shape[0]
    shp = f.shape
    f2 = f.reshape(-1, shp[-1])
    ks2 = ks.reshape(rm1, -1, shp[-1])
    pd = (-shp[-1]) % 128
    pt = (-f2.shape[0]) % block_t
    T, d = f2.shape
    f2 = jnp.pad(f2, ((0, pt), (0, pd)))
    ks2 = jnp.pad(ks2, ((0, 0), (0, pt), (0, pd)))
    out = kernel.xor_decode_pallas(f2, ks2, block_t=block_t,
                                   interpret=not _on_tpu())
    return out[:T, :d].reshape(shp)
