"""Jit'd public wrapper for the WKV scan kernel: model-layout adaptation
([B, S, h, N] <-> [B*h, S, N]), sequence padding, interpret fallback."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def wkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             u: jax.Array, initial_state: Optional[jax.Array] = None, *,
             chunk: int = 64) -> Tuple[jax.Array, jax.Array]:
    """Model layout: r, k, log_w [B, S, h, Nk]; v [B, S, h, Nv]; u [h, Nk];
    initial_state [B, h, Nk, Nv] (zeros if None).
    Returns (out [B, S, h, Nv], final_state [B, h, Nk, Nv])."""
    B, S, h, Nk = r.shape
    Nv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, h, Nk, Nv), jnp.float32)

    to_bh = lambda x: x.transpose(0, 2, 1, 3).reshape(B * h, S, x.shape[-1])
    rb, kb, vb, wb = map(to_bh, (r, k, v, log_w))
    ub = jnp.broadcast_to(u[None], (B, h, Nk)).reshape(B * h, Nk)
    s0 = initial_state.reshape(B * h, Nk, Nv)

    pad = (-S) % chunk
    if pad:
        rb = jnp.pad(rb, ((0, 0), (0, pad), (0, 0)))
        kb = jnp.pad(kb, ((0, 0), (0, pad), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pad), (0, 0)))
        # padded steps must not decay the state: log_w = 0 and k = 0 there
        wb = jnp.pad(wb, ((0, 0), (0, pad), (0, 0)))

    out, sT = kernel.wkv_scan_pallas(rb, kb, vb, wb, ub, s0, chunk=chunk,
                                     interpret=not _on_tpu())
    out = out[:, :S].reshape(B, h, S, Nv).transpose(0, 2, 1, 3)
    return out, sT.reshape(B, h, Nk, Nv)
