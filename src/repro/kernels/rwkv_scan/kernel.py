"""Pallas TPU kernel for the chunked RWKV6 WKV scan (long-context hot spot).

Implements the same chunked gated linear recurrence as
:func:`repro.models.linrec.chunked_linear_recurrence` (mode='rwkv'):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Grid: (B*h, n_chunks) — the chunk axis is minormost, so it executes
sequentially on TPU and the cross-chunk state lives in a VMEM scratch
accumulator carried across grid steps (the TPU-idiomatic replacement for
a sequential scan over HBM).

Per-chunk work in VMEM: all pairwise decays exp(A_i - A_j), i >= j, with A
the running log-decay cumsum — every exponent <= 0, numerically safe.
Block shapes: (C, Nk) inputs, (Nk, Nv) state; C defaults to 64 to bound
the (C, C, Nk) intra-chunk gate tensor in VMEM (64*64*64*4 B = 1 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                state_ref, *, nc: int, C: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    rb = r_ref[0].astype(jnp.float32)          # [C, Nk]
    kb = k_ref[0].astype(jnp.float32)
    vb = v_ref[0].astype(jnp.float32)          # [C, Nv]
    wb = w_ref[0].astype(jnp.float32)          # [C, Nk] log decays (<= 0)
    u = u_ref[0].astype(jnp.float32)           # [Nk]

    A = jnp.cumsum(wb, axis=0)                 # [C, Nk]
    A_total = A[-1]                            # [Nk]
    A_q = A - wb                               # decay through t-1

    state = state_ref[...]                     # [Nk, Nv]
    # inter-chunk: r_t dressed with exp(A_q) reads the carried state
    r_in = rb * jnp.exp(A_q)
    out = jax.lax.dot_general(r_in, state, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    # intra-chunk: pairwise exponents A_q[t] - A[s]  (<= 0 for s < t)
    expo = A_q[:, None, :] - A[None, :, :]             # [C, C, Nk]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    tri = (s_idx < t_idx)
    gate = jnp.where(tri[:, :, None], jnp.exp(expo), 0.0)
    M = jnp.einsum("tk,sk,tsk->ts", rb, kb, gate)      # [C, C]
    diag = jnp.sum(rb * u[None, :] * kb, axis=-1)      # [C] bonus term
    M = M + jnp.where(t_idx == s_idx, diag[:, None], 0.0)
    out = out + jax.lax.dot_general(M, vb, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    # state update: S' = diag(e^{A_total}) S + sum_s k_s e^{A_total - A_s} v_s
    k_dress = kb * jnp.exp(A_total[None, :] - A)       # [C, Nk]
    state_ref[...] = (state * jnp.exp(A_total)[:, None]
                      + jax.lax.dot_general(
                          k_dress, vb, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(c == nc - 1)
    def _fin():
        sT_ref[0] = state_ref[...]


def wkv_scan_pallas(r: jax.Array, k: jax.Array, v: jax.Array,
                    log_w: jax.Array, u: jax.Array, s0: jax.Array, *,
                    chunk: int = 64, interpret: bool = True):
    """r, k, log_w: [BH, S, Nk]; v: [BH, S, Nv]; u: [BH, Nk];
    s0: [BH, Nk, Nv] initial state.  S % chunk == 0 (ops.py pads).
    Returns (out [BH, S, Nv] in v dtype, final state [BH, Nk, Nv] fp32)."""
    BH, S, Nk = r.shape
    Nv = v.shape[-1]
    nc = S // chunk
    kern = functools.partial(_wkv_kernel, nc=nc, C=chunk)
    out, sT = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((BH, S, Nv), v.dtype),
                   jax.ShapeDtypeStruct((BH, Nk, Nv), jnp.float32)),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, Nk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Nk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Nv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Nk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Nk), lambda b, c: (b, 0)),
            pl.BlockSpec((1, Nk, Nv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((1, chunk, Nv), lambda b, c: (b, c, 0)),
                   pl.BlockSpec((1, Nk, Nv), lambda b, c: (b, 0, 0))),
        scratch_shapes=[pltpu.VMEM((Nk, Nv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u, s0)
    return out, sT
