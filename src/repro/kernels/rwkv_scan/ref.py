"""Pure-jnp oracle for the WKV scan kernel: the chunked linear recurrence
of :mod:`repro.models.linrec` mapped over the kernel's [BH, S, N] layout."""
from __future__ import annotations

from functools import partial

import jax

from ...models.linrec import chunked_linear_recurrence


def wkv_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array,
                 log_w: jax.Array, u: jax.Array, s0: jax.Array, *,
                 chunk: int = 64):
    """Same signature as wkv_scan_pallas: r/k/log_w [BH, S, Nk],
    v [BH, S, Nv], u [BH, Nk], s0 [BH, Nk, Nv]."""
    def one(r1, k1, v1, w1, u1, s1):
        out, sT = chunked_linear_recurrence(
            r1[None, :, None, :], k1[None, :, None, :], v1[None, :, None, :],
            w1[None, :, None, :], u=u1[None, :], initial_state=s1[None, None],
            mode="rwkv", chunk=chunk, return_state=True)
        return out[0, :, 0], sT[0, 0]
    return jax.vmap(one)(r, k, v, log_w, u, s0)
