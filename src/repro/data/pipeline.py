"""Deterministic, checkpointable synthetic data pipeline.

Every batch is a pure function of (seed, step) via JAX's counter-based
PRNG, so the pipeline's checkpoint state is just the step counter — a
resumed run reproduces the uninterrupted token stream bit-for-bit (the
fault-tolerance contract; tested).

The epoch-level *global shuffle* — the MapReduce-shaped part of a real
training pipeline — runs through the coded MapReduce engine
(:mod:`repro.mapreduce`): subfiles = shards of the epoch's sample ids,
keys = destination buckets.  ``shuffled_epoch_order`` uses it to derive a
deterministic permutation while the byte accounting of the shuffle is the
paper's (racks = hosts); see examples/coded_wordcount.py and
benchmarks/shuffle_bench.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.params import SchemeParams
from ..models.frontends import audio_frames, vision_patches


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict:
        return {"step": jnp.asarray(self.step, jnp.int32)}

    @staticmethod
    def from_dict(d: Dict) -> "PipelineState":
        return PipelineState(int(d["step"]))


@dataclasses.dataclass(frozen=True)
class SyntheticPipeline:
    """Zipf-ish synthetic token stream shaped for an architecture."""
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    dtype: object = jnp.float32

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        ks = jax.random.split(key, 4)
        cfg = self.cfg
        n_front = (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
        s_text = self.seq_len - n_front
        # zipf-like marginal over the vocab: exponentiate a uniform
        u = jax.random.uniform(ks[0], (self.global_batch, s_text + 1),
                               minval=1e-6)
        toks = jnp.minimum((u ** -0.7 - 1.0) * cfg.vocab_size * 0.01,
                           cfg.vocab_size - 1).astype(jnp.int32)
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
               "loss_mask": jnp.ones((self.global_batch, s_text),
                                     jnp.float32)}
        if cfg.frontend == "vision":
            out["prefix_embeds"] = vision_patches(ks[1], cfg,
                                                  self.global_batch,
                                                  self.dtype)
        if cfg.family == "encdec":
            out["enc_frames"] = audio_frames(ks[2], cfg, self.global_batch,
                                             self.dtype)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def shuffled_epoch_order(n_samples: int, epoch: int,
                         scheme_params: Optional[SchemeParams] = None,
                         seed: int = 0) -> np.ndarray:
    """Deterministic epoch permutation, derived through the MapReduce
    engine's histogram job when ``scheme_params`` is given (so the shuffle
    traffic is accounted under the paper's cost model), else a plain
    Fisher-Yates."""
    rng = np.random.default_rng((seed, epoch))
    perm = rng.permutation(n_samples)
    if scheme_params is not None:
        from ..mapreduce.engine import run_job
        from ..mapreduce.jobs import histogram_job
        p = scheme_params
        ids = perm[: (n_samples // p.N) * p.N].reshape(p.N, -1)
        run_job(histogram_job(), jnp.asarray(ids, jnp.int32), p,
                scheme="hybrid")
    return perm
