# Test entry points.  `make test` is the fast default profile (skips the
# multidevice subprocess drivers, ~5 min of wall clock); `make test-all`
# is the full tier-1 suite in one command.
PYTEST ?= python -m pytest

.PHONY: test test-all bench bench-pipeline bench-sim bench-locality \
	bench-resilience bench-faults bench-table1 bench-scale bench-obs \
	bench-blame bench-calibration bench-history-check obs-report

test:
	$(PYTEST) -q -m "not slow"

test-all:
	$(PYTEST) -q

bench:
	PYTHONPATH=src python benchmarks/shuffle_bench.py

bench-pipeline:
	PYTHONPATH=src python benchmarks/pipeline_bench.py

bench-sim:
	PYTHONPATH=src python benchmarks/sim_bench.py

bench-locality:
	PYTHONPATH=src python benchmarks/table2_locality.py

bench-resilience:
	PYTHONPATH=src python benchmarks/resilience_bench.py

bench-faults:
	PYTHONPATH=src python benchmarks/faults_bench.py

bench-table1:
	PYTHONPATH=src python benchmarks/table1_costs.py

bench-scale:
	PYTHONPATH=src python benchmarks/scale_bench.py

bench-obs:
	PYTHONPATH=src python benchmarks/obs_bench.py

bench-blame:
	PYTHONPATH=src python benchmarks/blame_bench.py

bench-calibration:
	PYTHONPATH=src python benchmarks/calibration_bench.py

bench-history-check:
	PYTHONPATH=src python benchmarks/history.py check

obs-report:
	PYTHONPATH=src python -m repro.obs.report
